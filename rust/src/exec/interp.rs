//! The interpreter proper: executes a [`Program`] over real buffers.
//!
//! Name-lookup audit: this naive engine deliberately resolves scope
//! names through string maps *per iteration* — it is the readable
//! ground truth, not a hot path. The only name lookups that matter for
//! performance are `Buffers::id_of` (now a map, O(log n)) at
//! allocation/output-collection time; everything per-iteration-hot
//! lives in `plan.rs`, which slot-resolves names once per block.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::ir::{AggOp, Block, BufKind, Program, RefDir, Statement};
use crate::poly::Affine;

use super::buffer::{BufferPool, Buffers};
use super::trace::{AccessEvent, NullSink, Sink};

/// Execution-engine selection (see the engine table in [`super`]).
/// With `workers > 1`, the engine names the per-chunk executor the
/// parallel dispatcher uses (`Naive` chunks run planned — the naive
/// interpreter is not chunkable).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// The naive interpreter: readable ground truth, the only engine
    /// executing `Special` statements and driving trace sinks.
    Naive,
    /// Serial plan compilation (`exec::plan`): slot-resolved odometer.
    #[default]
    Planned,
    /// Plan compilation + leaf-kernel lowering (`exec::kernel`): fused
    /// run-level kernels with hoisted checks, guarded-odometer fallback.
    Kernel,
    /// Inter-op DAG scheduling over a persistent worker pool
    /// (`exec::dataflow`): independent ops overlap across compute
    /// units, each op's chunks run the kernel lowering with
    /// work-stealing.
    Dataflow,
}

impl Engine {
    pub fn name(self) -> &'static str {
        match self {
            Engine::Naive => "naive",
            Engine::Planned => "planned",
            Engine::Kernel => "kernel",
            Engine::Dataflow => "dataflow",
        }
    }

    pub fn parse(s: &str) -> Option<Engine> {
        Some(match s {
            "naive" => Engine::Naive,
            "planned" => Engine::Planned,
            "kernel" => Engine::Kernel,
            "dataflow" => Engine::Dataflow,
            _ => return None,
        })
    }
}

/// Execution options.
#[derive(Debug, Clone)]
pub struct ExecOptions {
    /// Permit multiple writes through `assign` refinements (needed for
    /// inout-style updates some passes produce; default off so Def-2
    /// violations surface as errors).
    pub relaxed_assign: bool,
    /// Upper bound on executed leaf iterations (runaway guard). On the
    /// parallel path the budget applies per worker.
    pub max_iterations: u64,
    /// Compute units to execute across (see `exec::parallel`). `0` or
    /// `1` selects serial execution — always available as the fallback,
    /// so any divergence can be bisected by re-running serially.
    pub workers: usize,
    /// Which engine executes op blocks — serially, or per worker chunk
    /// when `workers > 1`. Defaults to the serial plan.
    pub engine: Engine,
    /// Execute kernel-engine lane bodies through the SIMD-shaped
    /// chunked kernels (`exec::simd`). Off retains the per-element
    /// lane interpreter — bitwise identical, used as the measured
    /// baseline for the simd speedup gate (`stripe run --simd-check`).
    /// Ignored by the naive and planned engines.
    pub simd: bool,
    /// Optional page pool: buffers draw their backing pages from it and
    /// return them when the run finishes, so repeated requests (the
    /// coordinator's service path) recycle allocations instead of
    /// paying fresh heap per request. `None` = plain allocation.
    pub pool: Option<Arc<BufferPool>>,
    /// Optional persistent compute pool for the dataflow engine: worker
    /// threads recycled across requests (the coordinator's service
    /// shares one, like its `BufferPool`). `None` = the dataflow run
    /// creates its own pool of `workers` threads — still one spawn
    /// batch per run, never per op. Ignored by the other engines.
    pub compute: Option<Arc<super::dataflow::ComputePool>>,
    /// Shard topology: when set (and the program has no specials),
    /// `run_program_with` routes to the sharded engine (`exec::shard`),
    /// splitting the op DAG across the topology's heterogeneous
    /// targets. Overrides `engine`/`workers` dispatch; `None` (the
    /// default) leaves the single-target engines in charge.
    pub shards: Option<Arc<crate::hw::shard::ShardTopology>>,
}

impl ExecOptions {
    /// Serial defaults with a worker-pool size (typically a target's
    /// `MachineConfig::compute_units`).
    pub fn with_workers(workers: usize) -> ExecOptions {
        ExecOptions { workers, ..ExecOptions::default() }
    }
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            relaxed_assign: false,
            max_iterations: 200_000_000,
            workers: 1,
            engine: Engine::default(),
            simd: true,
            pool: None,
            compute: None,
            shards: None,
        }
    }
}

/// Execution failure.
#[derive(Debug)]
pub struct ExecError {
    pub block: String,
    pub message: String,
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "exec error in {}: {}", self.block, self.message)
    }
}

impl std::error::Error for ExecError {}

/// A resolved buffer view during execution.
#[derive(Debug, Clone)]
struct View {
    buf: usize,
    /// Flat element offset of the view origin.
    offset: i64,
    /// Physical strides per logical dimension.
    strides: Vec<i64>,
    agg: AggOp,
}

/// Run `program` with the given inputs/weights (`name -> values`).
/// Returns the output buffers (`name -> values`). Uses a null sink.
///
/// Routes through the plan-compiled fast path (`exec::plan`) unless the
/// program uses `Special` statements, which only the naive interpreter
/// executes.
pub fn run_program(
    program: &Program,
    inputs: &BTreeMap<String, Vec<f32>>,
) -> Result<BTreeMap<String, Vec<f32>>, ExecError> {
    run_program_with(program, inputs, &ExecOptions::default())
}

/// Run with explicit options, choosing the execution engine:
/// `Special`-bearing programs take the naive interpreter (the only path
/// that executes specials); `opts.shards` takes the multi-target
/// sharded scheduler (`exec::shard`); `Engine::Dataflow` takes the
/// inter-op DAG scheduler (`exec::dataflow`); `opts.workers > 1` takes
/// the per-op parallel dispatcher (`exec::parallel`, which runs each
/// chunk on `opts.engine`); otherwise `opts.engine` selects between the
/// naive interpreter, the serial plan, and the leaf-kernel engine.
pub fn run_program_with(
    program: &Program,
    inputs: &BTreeMap<String, Vec<f32>>,
    opts: &ExecOptions,
) -> Result<BTreeMap<String, Vec<f32>>, ExecError> {
    let mut has_special = false;
    program.main.walk(&mut |b| {
        has_special |= b.stmts.iter().any(|s| matches!(s, Statement::Special(_)));
    });
    if has_special {
        run_program_sink(program, inputs, opts, &mut NullSink)
    } else if let Some(topo) = &opts.shards {
        super::shard::run_program_sharded(program, inputs, topo, opts).map(|(out, _)| out)
    } else if opts.engine == Engine::Dataflow {
        super::dataflow::run_program_dataflow(program, inputs, opts).map(|(out, _)| out)
    } else if opts.workers > 1 {
        super::parallel::run_program_parallel(program, inputs, opts).map(|(out, _)| out)
    } else {
        match opts.engine {
            Engine::Naive => run_program_sink(program, inputs, opts, &mut NullSink),
            Engine::Planned => {
                super::plan::run_program_planned(program, inputs, opts, &mut NullSink)
            }
            Engine::Kernel => {
                super::kernel::run_program_kernel(program, inputs, opts).map(|(out, _)| out)
            }
            Engine::Dataflow => unreachable!("dispatched above"),
        }
    }
}

/// Run with explicit options and an access sink.
pub fn run_program_sink(
    program: &Program,
    inputs: &BTreeMap<String, Vec<f32>>,
    opts: &ExecOptions,
    sink: &mut dyn Sink,
) -> Result<BTreeMap<String, Vec<f32>>, ExecError> {
    let mut bufs = Buffers::with_pool(opts.pool.clone());
    // Allocate program buffers with their declared storage dtype
    // (program-level buffers are typed; block-local scratch below
    // stays f32 — identical to the planned/kernel engines, which is
    // what keeps all engines bit-exact per dtype).
    for b in &program.buffers {
        let span = b.ttype.span_elems() as usize;
        match b.kind {
            BufKind::Input | BufKind::Weight => {
                let vals = inputs.get(&b.name).ok_or_else(|| ExecError {
                    block: "main".into(),
                    message: format!("missing input buffer {:?}", b.name),
                })?;
                if vals.len() != span {
                    return Err(ExecError {
                        block: "main".into(),
                        message: format!(
                            "input {:?} has {} elements, expected {span}",
                            b.name,
                            vals.len()
                        ),
                    });
                }
                bufs.alloc_init_dtype(&b.name, vals.clone(), b.ttype.dtype);
            }
            BufKind::Output | BufKind::Temp => {
                bufs.alloc_dtype(&b.name, span, b.ttype.dtype);
            }
        }
    }
    // Root scope from main's refinements.
    let mut scope: BTreeMap<String, View> = BTreeMap::new();
    for r in &program.main.refs {
        let (buf, base) = if r.dir == RefDir::Temp {
            // main-level temps may alias program Temp buffers by name, or
            // be fresh allocations.
            match bufs.id_of(&r.into) {
                Some(id) => (id, 0i64),
                None => (bufs.alloc(&r.into, r.ttype.span_elems() as usize), 0i64),
            }
        } else {
            let id = bufs.id_of(&r.from).ok_or_else(|| ExecError {
                block: "main".into(),
                message: format!("refinement {:?}: unknown buffer {:?}", r.into, r.from),
            })?;
            // main refinement accesses must be constant (no idxs in scope)
            let base: i64 = r
                .access
                .iter()
                .zip(r.ttype.strides())
                .map(|(a, s)| a.offset * s)
                .sum();
            (id, base)
        };
        scope.insert(
            r.into.clone(),
            View { buf, offset: base, strides: r.ttype.strides(), agg: r.agg },
        );
    }

    let mut exec = Exec { bufs: &mut bufs, opts, sink, executed: 0, scratch: Default::default() };
    let empty_env = IdxEnv::default();
    for st in &program.main.stmts {
        if let Statement::Block(b) = st {
            exec.sink.on_op_boundary(&b.name);
        }
        exec.exec_stmt(st, &empty_env, &scope, &program.main.name)?;
    }

    // Collect outputs, then hand the pages back to the pool (if any).
    let mut out = BTreeMap::new();
    for b in program.buffers_of(BufKind::Output) {
        let id = bufs.id_of(&b.name).unwrap();
        out.insert(b.name.clone(), bufs.snapshot(id));
    }
    bufs.release();
    Ok(out)
}

/// Index bindings for one block level: names and values, including
/// passed indexes.
#[derive(Debug, Default, Clone)]
struct IdxEnv {
    names: Vec<String>,
    vals: Vec<i64>,
}

struct Exec<'a> {
    bufs: &'a mut Buffers,
    opts: &'a ExecOptions,
    sink: &'a mut dyn Sink,
    executed: u64,
    /// Block-local scratch allocations, reused across iterations (a
    /// fresh allocation per iteration would both leak memory and make
    /// every scratch access a cold cache-sim miss). Keyed by
    /// (block path, refinement name); write-tracking is reset on reuse
    /// so Definition-2 semantics are per-iteration fresh.
    scratch: std::collections::BTreeMap<(String, String), usize>,
}

impl<'a> Exec<'a> {
    fn exec_stmt(
        &mut self,
        st: &Statement,
        idx_env: &IdxEnv,
        scope: &BTreeMap<String, View>,
        path: &str,
    ) -> Result<(), ExecError> {
        match st {
            Statement::Block(b) => self.exec_block(b, idx_env, scope, path),
            other => Err(ExecError {
                block: path.to_string(),
                message: format!(
                    "scalar statement outside an iterating block: {other:?} \
                     (main-level statements must be blocks)"
                ),
            }),
        }
    }

    fn exec_block(
        &mut self,
        b: &Block,
        parent_env: &IdxEnv,
        parent_scope: &BTreeMap<String, View>,
        path: &str,
    ) -> Result<(), ExecError> {
        let path = format!("{path}/{}", b.name);
        let err = |m: String| ExecError { block: path.clone(), message: m };

        // Split indexes into ranged and passed.
        let mut names: Vec<String> = Vec::with_capacity(b.idxs.len());
        let mut ranged: Vec<(usize, u64)> = Vec::new(); // (slot, range)
        let mut passed: Vec<(usize, &Affine)> = Vec::new();
        for idx in &b.idxs {
            let slot = names.len();
            names.push(idx.name.clone());
            match &idx.affine {
                None => ranged.push((slot, idx.range)),
                Some(a) => passed.push((slot, a)),
            }
        }
        let mut vals = vec![0i64; names.len()];
        // Passed indexes are constant w.r.t. this block's own iteration.
        for (slot, a) in &passed {
            vals[*slot] = a.eval_slices(&parent_env.names, &parent_env.vals);
        }

        // Iterate the rectilinear box; filter by constraints.
        let mut counters = vec![0u64; ranged.len()];
        'outer: loop {
            self.executed += 1;
            if self.executed > self.opts.max_iterations {
                return Err(err("iteration budget exceeded".into()));
            }
            for (k, (slot, _)) in ranged.iter().enumerate() {
                vals[*slot] = counters[k] as i64;
            }
            let satisfied = b
                .constraints
                .iter()
                .all(|c| c.eval_slices(&names, &vals) >= 0);
            if satisfied {
                self.exec_iteration(b, &names, &vals, parent_scope, &path)?;
            }
            // Advance odometer (last index innermost).
            let mut k = ranged.len();
            loop {
                if k == 0 {
                    break 'outer;
                }
                k -= 1;
                counters[k] += 1;
                if counters[k] < ranged[k].1 {
                    break;
                }
                counters[k] = 0;
            }
            if ranged.is_empty() {
                break;
            }
        }
        Ok(())
    }

    fn exec_iteration(
        &mut self,
        b: &Block,
        names: &[String],
        vals: &[i64],
        parent_scope: &BTreeMap<String, View>,
        path: &str,
    ) -> Result<(), ExecError> {
        let err = |m: String| ExecError { block: path.to_string(), message: m };
        // Resolve refinements at this iteration point.
        let mut scope: BTreeMap<String, View> = BTreeMap::new();
        for r in &b.refs {
            let view = if r.dir == RefDir::Temp {
                let key = (path.to_string(), r.into.clone());
                let id = match self.scratch.get(&key) {
                    Some(&id) => {
                        self.bufs.reset_written(id);
                        id
                    }
                    None => {
                        let id = self
                            .bufs
                            .alloc(&format!("{path}/{}", r.into), r.ttype.span_elems() as usize);
                        self.scratch.insert(key, id);
                        id
                    }
                };
                View { buf: id, offset: 0, strides: r.ttype.strides(), agg: r.agg }
            } else {
                let pv = parent_scope
                    .get(&r.from)
                    .ok_or_else(|| err(format!("no parent buffer {:?}", r.from)))?;
                if pv.strides.len() != r.access.len() {
                    return Err(err(format!(
                        "refinement {:?}: access rank {} vs parent rank {}",
                        r.into,
                        r.access.len(),
                        pv.strides.len()
                    )));
                }
                let mut offset = pv.offset;
                for (a, s) in r.access.iter().zip(&pv.strides) {
                    offset += a.eval_slices(names, vals) * s;
                }
                View { buf: pv.buf, offset, strides: r.ttype.strides(), agg: r.agg }
            };
            scope.insert(r.into.clone(), view);
        }

        // Execute the statement list serially.
        let mut scalars: BTreeMap<&str, f32> = BTreeMap::new();
        let this_env = IdxEnv { names: names.to_vec(), vals: vals.to_vec() };
        for st in &b.stmts {
            match st {
                Statement::Load { from, into } => {
                    let v = scope.get(from).ok_or_else(|| err(format!("load: no buffer {from:?}")))?;
                    self.sink.on_access(AccessEvent { buf: v.buf, elem: v.offset, write: false });
                    let value = self.bufs.read(v.buf, v.offset).map_err(err)?;
                    scalars.insert(into, value);
                }
                Statement::Store { from, into } => {
                    let value = *scalars
                        .get(from.as_str())
                        .ok_or_else(|| err(format!("store: undefined scalar {from:?}")))?;
                    let v = scope.get(into).ok_or_else(|| err(format!("store: no buffer {into:?}")))?;
                    self.sink.on_access(AccessEvent { buf: v.buf, elem: v.offset, write: true });
                    self.bufs
                        .store(v.buf, v.offset, value, v.agg, self.opts.relaxed_assign)
                        .map_err(err)?;
                }
                Statement::Intrinsic { op, inputs, output } => {
                    let mut args = [0f32; 3];
                    if inputs.len() != op.arity() {
                        return Err(err(format!("intrinsic {} arity mismatch", op.name())));
                    }
                    for (i, name) in inputs.iter().enumerate() {
                        args[i] = *scalars
                            .get(name.as_str())
                            .ok_or_else(|| err(format!("undefined scalar {name:?}")))?;
                    }
                    scalars.insert(output, op.eval(&args[..inputs.len()]));
                }
                Statement::Constant { output, value } => {
                    scalars.insert(output, *value as f32);
                }
                Statement::Block(cb) => {
                    self.exec_block(cb, &this_env, &scope, path)?;
                }
                Statement::Special(sp) => {
                    self.exec_special(sp, &scope, path)?;
                }
            }
        }
        Ok(())
    }

    /// Execute a special function. The library ships `copy`, `zero`, and
    /// `fill` (others lower to blocks in this reproduction; scatter and
    /// gather are exercised in tests).
    fn exec_special(
        &mut self,
        sp: &crate::ir::Special,
        scope: &BTreeMap<String, View>,
        path: &str,
    ) -> Result<(), ExecError> {
        let err = |m: String| ExecError { block: path.to_string(), message: m };
        match sp.name.as_str() {
            // fill(out) value=v : set the view's origin element.
            "fill" => {
                let v: f32 = sp
                    .attrs
                    .get("value")
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| err("fill requires numeric value attr".into()))?;
                let out = sp.outputs.first().ok_or_else(|| err("fill needs an output".into()))?;
                let view = scope.get(out).ok_or_else(|| err(format!("no buffer {out:?}")))?;
                self.sink.on_access(AccessEvent { buf: view.buf, elem: view.offset, write: true });
                self.bufs
                    .store(view.buf, view.offset, v, view.agg, true)
                    .map_err(err)?;
                Ok(())
            }
            other => Err(err(format!("unknown special function {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::{contraction, fig5_conv_block, identity_access, Operand};
    use crate::ir::{Buffer, DType, IntrOp, Program, TensorType};

    /// Reference conv for the Fig.-5 workload, in plain Rust.
    fn ref_conv(i: &[f32], f: &[f32]) -> Vec<f32> {
        let (h, w, ci, co) = (12usize, 16usize, 8usize, 16usize);
        let mut o = vec![0f32; h * w * co];
        for x in 0..h {
            for y in 0..w {
                for k in 0..co {
                    let mut acc = 0f32;
                    for di in 0..3usize {
                        for dj in 0..3usize {
                            let xx = x as i64 + di as i64 - 1;
                            let yy = y as i64 + dj as i64 - 1;
                            if xx < 0 || xx >= h as i64 || yy < 0 || yy >= w as i64 {
                                continue;
                            }
                            for c in 0..ci {
                                let iv = i[(xx as usize * w + yy as usize) * ci + c];
                                let fv = f[((di * 3 + dj) * co + k) * ci + c];
                                acc += iv * fv;
                            }
                        }
                    }
                    o[(x * w + y) * co + k] = acc;
                }
            }
        }
        o
    }

    fn conv_program() -> Program {
        let mut p = Program::new(
            "conv",
            vec![
                Buffer {
                    name: "I".into(),
                    kind: BufKind::Input,
                    ttype: TensorType::contiguous(DType::F32, &[12, 16, 8]),
                },
                Buffer {
                    name: "F".into(),
                    kind: BufKind::Weight,
                    ttype: TensorType::contiguous(DType::F32, &[3, 3, 16, 8]),
                },
                Buffer {
                    name: "O".into(),
                    kind: BufKind::Output,
                    ttype: TensorType::contiguous(DType::F32, &[12, 16, 16]),
                },
            ],
        );
        let mut conv = fig5_conv_block();
        // Use f32 leaf types (the builder's Fig.-5 version uses i8 for
        // print fidelity; execution semantics are identical).
        for r in &mut conv.refs {
            r.ttype.dtype = DType::F32;
        }
        p.main.stmts.push(Statement::Block(Box::new(conv)));
        p
    }

    #[test]
    fn conv_matches_reference() {
        let p = conv_program();
        let mut rng = crate::util::rng::Rng::new(1);
        let i: Vec<f32> = rng.normal_vec(12 * 16 * 8, 1.0);
        let f: Vec<f32> = rng.normal_vec(3 * 3 * 16 * 8, 0.5);
        let mut inputs = BTreeMap::new();
        inputs.insert("I".to_string(), i.clone());
        inputs.insert("F".to_string(), f.clone());
        let out = run_program(&p, &inputs).unwrap();
        let got = &out["O"];
        let want = ref_conv(&i, &f);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
    }

    #[test]
    fn elementwise_relu_runs() {
        let t = TensorType::contiguous(DType::F32, &[8]);
        let mut p = Program::new(
            "relu",
            vec![
                Buffer { name: "I".into(), kind: BufKind::Input, ttype: t.clone() },
                Buffer { name: "O".into(), kind: BufKind::Output, ttype: t.clone() },
            ],
        );
        let b = crate::ir::builder::elementwise_unary(
            "relu",
            &[("x", 8)],
            Operand::new("O", identity_access(&["x"]), &t),
            Operand::new("I", identity_access(&["x"]), &t),
            &[IntrOp::Relu],
        );
        p.main.stmts.push(Statement::Block(Box::new(b)));
        let mut inputs = BTreeMap::new();
        inputs.insert("I".to_string(), vec![-2.0, -1.0, 0.0, 1.0, 2.0, -3.0, 4.0, -5.0]);
        let out = run_program(&p, &inputs).unwrap();
        assert_eq!(out["O"], vec![0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn maxpool_first_write_assigns() {
        // O[x] = max over w:2 of I[2x + w], with negative inputs —
        // correct only if the first write assigns (not max against 0).
        let ti = TensorType::contiguous(DType::F32, &[8]);
        let to = TensorType::contiguous(DType::F32, &[4]);
        let mut p = Program::new(
            "mp",
            vec![
                Buffer { name: "I".into(), kind: BufKind::Input, ttype: ti.clone() },
                Buffer { name: "O".into(), kind: BufKind::Output, ttype: to.clone() },
            ],
        );
        let b = contraction(
            "maxpool",
            &[("x", 4), ("w", 2)],
            vec![],
            Operand::new("O", vec![Affine::var("x")], &to),
            AggOp::Max,
            &[Operand::new(
                "I",
                vec![Affine::from_terms(&[("x", 2), ("w", 1)], 0)],
                &ti,
            )],
            IntrOp::Mul,
        );
        p.main.stmts.push(Statement::Block(Box::new(b)));
        let mut inputs = BTreeMap::new();
        inputs.insert("I".to_string(), vec![-5.0, -3.0, -1.0, -2.0, 7.0, 1.0, -4.0, -6.0]);
        let out = run_program(&p, &inputs).unwrap();
        assert_eq!(out["O"], vec![-3.0, -1.0, 7.0, -4.0]);
    }

    #[test]
    fn missing_input_is_error() {
        let p = conv_program();
        let e = run_program(&p, &BTreeMap::new()).unwrap_err();
        assert!(e.message.contains("missing input"));
    }

    #[test]
    fn max_iterations_triggers_cleanly_on_naive_path() {
        // The guard must surface as a clean error (not a hang or panic)
        // and must name the budget, on every execution engine.
        let p = conv_program();
        let inputs = crate::passes::equiv::gen_inputs(&p, 1);
        let opts = ExecOptions { max_iterations: 100, ..ExecOptions::default() };
        let e = run_program_sink(&p, &inputs, &opts, &mut NullSink).unwrap_err();
        assert!(e.message.contains("iteration budget"), "{e}");
    }

    #[test]
    fn max_iterations_triggers_cleanly_on_planned_path() {
        let p = conv_program();
        let inputs = crate::passes::equiv::gen_inputs(&p, 1);
        let opts = ExecOptions { max_iterations: 100, ..ExecOptions::default() };
        let e = super::super::plan::run_program_planned(&p, &inputs, &opts, &mut NullSink)
            .unwrap_err();
        assert!(e.message.contains("iteration budget"), "{e}");
    }

    #[test]
    fn max_iterations_triggers_cleanly_on_parallel_path() {
        let p = conv_program();
        let inputs = crate::passes::equiv::gen_inputs(&p, 1);
        let opts =
            ExecOptions { max_iterations: 100, workers: 4, ..ExecOptions::default() };
        let e = run_program_with(&p, &inputs, &opts).unwrap_err();
        assert!(e.message.contains("iteration budget"), "{e}");
    }

    #[test]
    fn engine_dispatch_is_bit_exact_across_engines() {
        let p = conv_program();
        let inputs = crate::passes::equiv::gen_inputs(&p, 3);
        let base = run_program(&p, &inputs).unwrap();
        for engine in [Engine::Naive, Engine::Planned, Engine::Kernel] {
            let opts = ExecOptions { engine, ..ExecOptions::default() };
            let out = run_program_with(&p, &inputs, &opts).unwrap();
            // Naive vs planned agree to the bit on this workload; the
            // kernel engine is pinned bit-exact by the differential
            // suite — here we only require engine dispatch to work.
            for (k, v) in &base {
                let w = &out[k];
                for (a, b) in v.iter().zip(w) {
                    assert!(
                        (a - b).abs() <= 1e-5 * 1.0f32.max(a.abs()),
                        "{:?} {k}: {a} vs {b}",
                        engine
                    );
                }
            }
        }
        assert_eq!(Engine::parse("kernel"), Some(Engine::Kernel));
        assert_eq!(Engine::parse("bogus"), None);
        assert_eq!(Engine::default().name(), "planned");
    }

    #[test]
    fn generous_budget_is_not_triggered() {
        let p = conv_program();
        let inputs = crate::passes::equiv::gen_inputs(&p, 1);
        let opts = ExecOptions { max_iterations: 10_000_000, ..ExecOptions::default() };
        assert!(run_program_sink(&p, &inputs, &opts, &mut NullSink).is_ok());
    }

    #[test]
    fn trace_sink_sees_conv_footprint() {
        let p = conv_program();
        let mut rng = crate::util::rng::Rng::new(2);
        let mut inputs = BTreeMap::new();
        inputs.insert("I".to_string(), rng.normal_vec(12 * 16 * 8, 1.0));
        inputs.insert("F".to_string(), rng.normal_vec(3 * 3 * 16 * 8, 1.0));
        let mut sink = super::super::trace::RecordingSink::default();
        run_program_sink(&p, &inputs, &ExecOptions::default(), &mut sink).unwrap();
        // Every output element is written; every input element read.
        assert_eq!(sink.elements_written(2).len(), 12 * 16 * 16);
        assert_eq!(sink.elements_read(0).len(), 12 * 16 * 8);
        assert_eq!(sink.boundaries.len(), 1);
    }
}
