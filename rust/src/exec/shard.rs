//! Sharded execution: one network split across multiple heterogeneous
//! simulated targets.
//!
//! The sixth engine stage. The dataflow engine (`exec::dataflow`)
//! overlaps independent ops across one homogeneous worker pool; this
//! module splits the same op DAG across the *shards* of a
//! [`ShardTopology`] — each shard a whole simulated machine with its
//! own compute-unit count — and schedules the shards asynchronously
//! over one persistent [`ComputePool`]:
//!
//! * **Assignment** ([`assign_shards`] / [`pin_shards`]): every
//!   top-level op is placed on exactly one shard.  The automatic
//!   search enumerates contiguous chain partitions of the op list
//!   (regions stay contiguous in program order, so cross-region
//!   hazards only point forward) and minimizes the modeled makespan —
//!   per-shard work weighted by the shard's roofline speed, plus the
//!   transfer term below (`cost::transfer::makespan`). The search is
//!   free to conclude that sharding is not worth it (everything on
//!   the fastest shard); [`pin_shards`] accepts any explicit
//!   placement, contiguous or not.
//! * **Scheduling**: the op hazard DAG is the dataflow engine's
//!   (RAW/WAR/WAW from flat footprints, forward edges only). A ready
//!   op dispatches only when *its shard* is idle — each shard executes
//!   at most one op at a time, which is what makes per-shard busy
//!   time, overlap, and imbalance meaningful. A dispatched op is
//!   chunked across **its own shard's** compute units (a 1-unit shard
//!   runs single-chunk ops while an 8-unit shard runs 16 stealable
//!   chunks next to it) into the shared pool.
//! * **Boundary hand-offs**: ops exchange data through the same
//!   copy-on-write master buffers and verified-disjoint merges as the
//!   other engines — a shard boundary changes *accounting*, never
//!   semantics. A [`TransferLedger`] records, per flat buffer range,
//!   which shard wrote it last; when an op dispatches, every read
//!   range last written by a *different* shard is charged to the
//!   inter-shard link in storage-dtype bytes. Program inputs and
//!   weights have no writer and are never charged (shards with fully
//!   disjoint working sets transfer zero bytes). Because every RAW
//!   hazard is a DAG edge, the ledger at dispatch time equals the
//!   program-order state, so the runtime byte count reproduces the
//!   static prediction in [`ShardAssignment`] exactly.
//! * **Bit-exactness**: unchanged from the parallel/dataflow engines
//!   and pinned by the differential sweep (naive ≡ planned ≡ kernel ≡
//!   parallel ≡ dataflow ≡ sharded, per storage dtype): same CoW
//!   fork / verified-disjoint merge per chunk, same inline fallback
//!   when a write target holds earlier data (an op spanning a shard
//!   boundary *serializes* rather than corrupting), same hazard
//!   serialization.
//!
//! [`run_program_sharded`] is selected by [`ExecOptions::shards`]
//! (`stripe run --shards t1,t2`); the coordinator's shard-aware
//! compile (per-shard pass pipelines and tuning) lives in
//! `coordinator::shard`.

use std::collections::BTreeMap;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use crate::cost::transfer::imbalance;
use crate::hw::shard::ShardTopology;
use crate::ir::{Block, BufKind, DType, Program, Statement};

use super::buffer::Buffers;
use super::dataflow::{
    build_dag, decide_dataflow, merge_op, ChunkDone, ComputePool, DfDecision, Flight, Job,
    OVERSUBSCRIPTION,
};
use super::interp::{ExecError, ExecOptions};
use super::parallel::{chunk_block, exec_chunk, split_range, OpParallelism};
use super::plan::{self, RootScope};
use super::ParallelReport;

/// Flat extents of one op against the root scope (buffer id, lo, hi).
type Extents = Option<Vec<(usize, i64, i64)>>;

/// Bytes one element of buffer `dt` occupies in storage (non-storage
/// dtypes are stored at f32 width — same rule as `exec::buffer`).
fn storage_bytes(dt: DType) -> u64 {
    if DType::STORAGE.contains(&dt) {
        dt.size_bytes()
    } else {
        DType::F32.size_bytes()
    }
}

/// Coalesce extents into disjoint per-buffer intervals so overlapping
/// refinements of one op never double-charge the link.
fn coalesce(ext: &[(usize, i64, i64)]) -> Vec<(usize, i64, i64)> {
    let mut sorted: Vec<(usize, i64, i64)> = ext.to_vec();
    sorted.sort_unstable();
    let mut out: Vec<(usize, i64, i64)> = Vec::with_capacity(sorted.len());
    for (id, lo, hi) in sorted {
        match out.last_mut() {
            Some((pid, _, phi)) if *pid == id && lo <= *phi + 1 => *phi = (*phi).max(hi),
            _ => out.push((id, lo, hi)),
        }
    }
    out
}

/// Last-writer bookkeeping per flat buffer range: which shard produced
/// the bytes currently live in each interval. Shared by the static
/// prediction and the runtime accounting, which is what makes them
/// agree byte-for-byte.
#[derive(Default)]
struct TransferLedger {
    spans: BTreeMap<usize, Vec<(i64, i64, usize)>>,
}

impl TransferLedger {
    /// Bytes of `reads` last written by a shard other than `shard`.
    fn charge(&self, reads: &Extents, shard: usize, elem_bytes: impl Fn(usize) -> u64) -> u64 {
        let Some(ext) = reads else { return 0 };
        let mut total = 0u64;
        for &(id, lo, hi) in &coalesce(ext) {
            let Some(spans) = self.spans.get(&id) else { continue };
            for &(slo, shi, s) in spans {
                if s != shard && slo <= hi && lo <= shi {
                    let olen = (hi.min(shi) - lo.max(slo) + 1) as u64;
                    total += olen * elem_bytes(id);
                }
            }
        }
        total
    }

    /// Record `writes` as now owned by `shard` (overwriting any prior
    /// owner of the overlapped ranges).
    fn record(&mut self, writes: &Extents, shard: usize) {
        let Some(ext) = writes else { return };
        for &(id, lo, hi) in &coalesce(ext) {
            let spans = self.spans.entry(id).or_default();
            let mut next = Vec::with_capacity(spans.len() + 1);
            for &(slo, shi, s) in spans.iter() {
                if shi < lo || slo > hi {
                    next.push((slo, shi, s));
                    continue;
                }
                if slo < lo {
                    next.push((slo, lo - 1, s));
                }
                if shi > hi {
                    next.push((hi + 1, shi, s));
                }
            }
            next.push((lo, hi, shard));
            *spans = next;
        }
    }
}

/// A placement of every top-level op on a shard, with the static
/// prediction of what executing it will cost.
#[derive(Debug, Clone)]
pub struct ShardAssignment {
    /// Op index (program order) → shard index.
    pub op_shard: Vec<usize>,
    /// Bytes predicted to cross the inter-shard link, from the same
    /// last-writer accounting the runtime uses — `--shard-check`
    /// asserts the runtime count equals this exactly.
    pub predicted_transfer_bytes: u64,
    /// Modeled compute seconds per shard (leaf iterations weighted by
    /// the shard's roofline speed).
    pub predicted_busy: Vec<f64>,
}

impl ShardAssignment {
    /// Ops placed on shard `s`.
    pub fn ops_on(&self, s: usize) -> usize {
        self.op_shard.iter().filter(|&&x| x == s).count()
    }

    /// One-line rendering for report summaries.
    pub fn summary_line(&self, topo: &ShardTopology) -> String {
        let parts: Vec<String> = topo
            .shards
            .iter()
            .enumerate()
            .map(|(s, spec)| format!("{}:{} op(s)", spec.name, self.ops_on(s)))
            .collect();
        format!(
            "assignment: {}; predicted transfer {} B",
            parts.join(", "),
            self.predicted_transfer_bytes
        )
    }
}

fn op_blocks(p: &Program) -> Result<Vec<&Block>, ExecError> {
    p.main
        .stmts
        .iter()
        .map(|st| match st {
            Statement::Block(b) => Ok(b),
            _ => Err(ExecError {
                block: "main".into(),
                message: "sharded execution requires main-level statements to be blocks".into(),
            }),
        })
        .collect()
}

/// Storage bytes per element of root-scope buffer `id`, statically:
/// program buffers carry their declared dtype, scope-allocated temps
/// are f32.
fn static_elem_bytes(p: &Program, id: usize) -> u64 {
    match p.buffers.get(id) {
        Some(b) => storage_bytes(b.ttype.dtype),
        None => DType::F32.size_bytes(),
    }
}

/// Static prediction for a placement: (link bytes, per-shard busy
/// seconds). Uses the identical ledger walk as the runtime, in program
/// order.
fn predict(
    p: &Program,
    topo: &ShardTopology,
    blocks: &[&Block],
    scope: &RootScope,
    op_shard: &[usize],
) -> (u64, Vec<f64>) {
    let reads: Vec<Extents> = blocks.iter().map(|b| plan::flat_read_extents(b, scope)).collect();
    let writes: Vec<Extents> =
        blocks.iter().map(|b| plan::flat_write_extents(b, scope)).collect();
    let mut busy = vec![0.0f64; topo.len()];
    let mut ledger = TransferLedger::default();
    let mut bytes = 0u64;
    for (i, b) in blocks.iter().enumerate() {
        let s = op_shard[i];
        // ~2 flops (one multiply-accumulate) per leaf iteration against
        // the shard's roofline peak: crude, but consistent across
        // shards, which is all the chain-partition search needs.
        busy[s] += 2.0 * b.total_leaf_iterations() as f64 / topo.speed(s);
        bytes += ledger.charge(&reads[i], s, |id| static_elem_bytes(p, id));
        ledger.record(&writes[i], s);
    }
    (bytes, busy)
}

/// Pin an explicit placement (one shard index per top-level op, any
/// shape — the directed boundary tests and the bench use this) and
/// compute its static prediction.
pub fn pin_shards(
    p: &Program,
    topo: &ShardTopology,
    op_shard: &[usize],
) -> Result<ShardAssignment, ExecError> {
    let err = |m: String| ExecError { block: "main".into(), message: m };
    let blocks = op_blocks(p)?;
    if op_shard.len() != blocks.len() {
        return Err(err(format!(
            "pinned assignment names {} op(s), program has {}",
            op_shard.len(),
            blocks.len()
        )));
    }
    if let Some(&bad) = op_shard.iter().find(|&&s| s >= topo.len()) {
        return Err(err(format!("pinned shard index {bad} out of range ({} shards)", topo.len())));
    }
    let scope = plan::symbolic_root_scope(p)?;
    let (bytes, busy) = predict(p, topo, &blocks, &scope, op_shard);
    Ok(ShardAssignment {
        op_shard: op_shard.to_vec(),
        predicted_transfer_bytes: bytes,
        predicted_busy: busy,
    })
}

/// Enumerate every way to cut `n` ops into `k` contiguous (possibly
/// empty) segments, calling `f` with the op→shard map.
fn for_each_chain(n: usize, k: usize, f: &mut impl FnMut(&[usize])) {
    let mut assign = vec![0usize; n];
    fn rec(assign: &mut Vec<usize>, from: usize, shard: usize, k: usize, f: &mut impl FnMut(&[usize])) {
        if shard + 1 == k {
            for a in assign[from..].iter_mut() {
                *a = shard;
            }
            f(assign);
            return;
        }
        for cut in from..=assign.len() {
            for a in assign[from..cut].iter_mut() {
                *a = shard;
            }
            rec(assign, cut, shard + 1, k, f);
        }
    }
    rec(&mut assign, 0, 0, k, f);
}

/// Number of chain partitions of `n` ops into `k` segments,
/// saturating: C(n + k - 1, k - 1).
fn chain_count(n: usize, k: usize) -> u64 {
    let mut c: u64 = 1;
    for i in 0..(k - 1) as u64 {
        c = c.saturating_mul(n as u64 + i + 1) / (i + 1);
        if c > 1_000_000 {
            return u64::MAX;
        }
    }
    c
}

/// Automatically place every top-level op on a shard: contiguous chain
/// partition of the op list minimizing the modeled makespan (per-shard
/// roofline-weighted work plus the link-transfer term). Falls back to
/// a work-balanced greedy cut when the exact enumeration would be too
/// large. The result may be degenerate (all ops on one shard) when the
/// model says transfers outweigh the parallelism — [`pin_shards`]
/// overrides.
pub fn assign_shards(p: &Program, topo: &ShardTopology) -> Result<ShardAssignment, ExecError> {
    let blocks = op_blocks(p)?;
    let n = blocks.len();
    let k = topo.len();
    let scope = plan::symbolic_root_scope(p)?;
    let score = |op_shard: &[usize]| -> (f64, u64, Vec<f64>) {
        let (bytes, busy) = predict(p, topo, &blocks, &scope, op_shard);
        (crate::cost::transfer::makespan(&busy, topo.link.seconds(bytes)), bytes, busy)
    };
    let mut best: Option<(f64, Vec<usize>)> = None;
    if chain_count(n, k) <= 200_000 {
        for_each_chain(n, k, &mut |cand| {
            let (s, _, _) = score(cand);
            if best.as_ref().map(|(b, _)| s < *b).unwrap_or(true) {
                best = Some((s, cand.to_vec()));
            }
        });
    } else {
        // Greedy: walk ops in order, advancing to the next shard when
        // the current one holds its proportional share of total work.
        let total: f64 = blocks.iter().map(|b| b.total_leaf_iterations() as f64).sum();
        let speed_sum: f64 = (0..k).map(|s| topo.speed(s)).sum();
        let mut cand = vec![0usize; n];
        let (mut shard, mut acc) = (0usize, 0.0f64);
        for (i, b) in blocks.iter().enumerate() {
            cand[i] = shard;
            acc += b.total_leaf_iterations() as f64;
            if shard + 1 < k && acc >= total * topo.speed(shard) / speed_sum {
                shard += 1;
                acc = 0.0;
            }
        }
        let (s, _, _) = score(&cand);
        best = Some((s, cand));
    }
    let (_, op_shard) = best.expect("chain enumeration yields at least one candidate");
    let (bytes, busy) = predict(p, topo, &blocks, &scope, &op_shard);
    Ok(ShardAssignment { op_shard, predicted_transfer_bytes: bytes, predicted_busy: busy })
}

/// Runtime per-shard lane of one sharded run.
#[derive(Debug, Clone)]
pub struct ShardLane {
    pub name: String,
    /// Compute units the shard chunks its ops across.
    pub units: usize,
    /// Ops this shard executed.
    pub ops: usize,
    /// Wall seconds this shard was occupied by an op.
    pub busy_s: f64,
    /// Bytes this shard read out of other shards' writes.
    pub transfer_in_bytes: u64,
}

/// Statistics of one sharded run.
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    pub lanes: Vec<ShardLane>,
    /// Total bytes that crossed the inter-shard link.
    pub transfer_bytes: u64,
    /// Modeled link seconds for those bytes (one hop per op with a
    /// non-empty transfer).
    pub transfer_seconds: f64,
    /// The assignment's static prediction — equals `transfer_bytes`
    /// (asserted by `--shard-check` and the boundary tests).
    pub predicted_transfer_bytes: u64,
    /// Most shards simultaneously occupied at any point.
    pub max_in_flight: usize,
    /// Ops that ran inline on the scheduler thread (stateful target,
    /// unresolved footprint, or no writes).
    pub inline_ops: usize,
    /// Worker threads in the shared pool.
    pub pool_size: usize,
}

impl ShardStats {
    /// Load imbalance across shard busy times (max/mean, 1.0 = even).
    pub fn imbalance(&self) -> f64 {
        let busy: Vec<f64> = self.lanes.iter().map(|l| l.busy_s).collect();
        imbalance(&busy)
    }

    /// One-line rendering for report summaries.
    pub fn summary_line(&self) -> String {
        let lanes: Vec<String> = self
            .lanes
            .iter()
            .map(|l| {
                format!(
                    "{}[{}u]: {} op(s), busy {:.1}ms, in {} B",
                    l.name,
                    l.units,
                    l.ops,
                    l.busy_s * 1e3,
                    l.transfer_in_bytes
                )
            })
            .collect();
        format!(
            "shards: {}; transfer {} B ({:.1}us modeled), imbalance {:.2}, \
             overlapped {}, inline {}, pool {}",
            lanes.join("; "),
            self.transfer_bytes,
            self.transfer_seconds * 1e6,
            self.imbalance(),
            self.max_in_flight,
            self.inline_ops,
            self.pool_size
        )
    }
}

/// Everything one sharded run reports: the per-op schedule (same shape
/// as the other engines), the shard lanes, and the assignment used.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub schedule: ParallelReport,
    pub stats: ShardStats,
    pub assignment: ShardAssignment,
}

/// Run a program across the shards of `topo`, placing ops with the
/// automatic chain-partition search. See the module docs; semantics
/// are bit-exact with the serial planned path.
pub fn run_program_sharded(
    program: &Program,
    inputs: &BTreeMap<String, Vec<f32>>,
    topo: &ShardTopology,
    opts: &ExecOptions,
) -> Result<(BTreeMap<String, Vec<f32>>, ShardReport), ExecError> {
    let assignment = assign_shards(program, topo)?;
    run_program_sharded_with(program, inputs, topo, assignment, opts)
}

/// Run with an explicit [`ShardAssignment`] (from [`assign_shards`] or
/// [`pin_shards`] — the coordinator's shard-aware compile pins the
/// placement it compiled each region for).
pub fn run_program_sharded_with(
    program: &Program,
    inputs: &BTreeMap<String, Vec<f32>>,
    topo: &ShardTopology,
    assignment: ShardAssignment,
    opts: &ExecOptions,
) -> Result<(BTreeMap<String, Vec<f32>>, ShardReport), ExecError> {
    let err = |m: String| ExecError { block: "main".into(), message: m };
    let nshards = topo.len();
    if nshards == 0 {
        return Err(err("shard topology is empty".into()));
    }
    let mut bufs = plan::alloc_program_buffers(program, inputs, opts.pool.clone())?;
    let scope = Arc::new(plan::build_root_scope(program, &mut bufs)?);
    let blocks = match op_blocks(program) {
        Ok(b) => b,
        Err(e) => {
            bufs.release();
            return Err(e);
        }
    };
    let n = blocks.len();
    if assignment.op_shard.len() != n {
        bufs.release();
        return Err(err(format!(
            "assignment names {} op(s), program has {n}",
            assignment.op_shard.len()
        )));
    }
    if let Some(&bad) = assignment.op_shard.iter().find(|&&s| s >= nshards) {
        bufs.release();
        return Err(err(format!("assignment shard index {bad} out of range ({nshards} shards)")));
    }
    let dag = build_dag(&blocks, &scope);
    let reads: Vec<Extents> = blocks.iter().map(|b| plan::flat_read_extents(b, &scope)).collect();
    let writes: Vec<Extents> =
        blocks.iter().map(|b| plan::flat_write_extents(b, &scope)).collect();
    // Storage width per root-scope buffer, resolved once (scope
    // allocation order matches the symbolic scope, so runtime charges
    // reproduce the static prediction).
    let widths: Vec<u64> = (0..bufs.count()).map(|id| storage_bytes(bufs.dtype_of(id))).collect();
    let elem_bytes = |id: usize| widths.get(id).copied().unwrap_or(4);

    let pool = match &opts.compute {
        Some(p) => Arc::clone(p),
        None => ComputePool::new(topo.total_units()),
    };
    // Chunk options: chunks must not recurse into the sharded or
    // dataflow engines (and must not keep the pool alive through its
    // own queue).
    let job_opts = ExecOptions { compute: None, shards: None, ..opts.clone() };

    let (done_tx, done_rx) = channel::<ChunkDone>();
    let mut indeg = dag.indeg.clone();
    let mut ready: std::collections::BTreeSet<usize> =
        (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut flights: Vec<Option<Flight>> = (0..n).map(|_| None).collect();
    let mut slots: Vec<Option<OpParallelism>> = vec![None; n];
    let mut shard_busy = vec![false; nshards];
    let mut op_start: Vec<Option<Instant>> = vec![None; n];
    let mut lanes: Vec<ShardLane> = topo
        .shards
        .iter()
        .map(|s| ShardLane {
            name: s.name.clone(),
            units: s.target.compute_units.max(1),
            ops: 0,
            busy_s: 0.0,
            transfer_in_bytes: 0,
        })
        .collect();
    let mut ledger = TransferLedger::default();
    let mut transfer_bytes = 0u64;
    let mut transfer_seconds = 0.0f64;
    let mut in_flight = 0usize;
    let mut max_in_flight = 0usize;
    let mut inline_ops = 0usize;
    let mut executed_hwm = 0u64;
    let mut failure: Option<ExecError> = None;

    loop {
        // Dispatch every ready op whose shard is idle, in program
        // order. (If nothing is in flight every shard is idle, so the
        // loop can never stall with work remaining.)
        while failure.is_none() {
            let Some(i) =
                ready.iter().copied().find(|&i| !shard_busy[assignment.op_shard[i]])
            else {
                break;
            };
            ready.remove(&i);
            let s = assignment.op_shard[i];
            let b = blocks[i];
            // Boundary hand-off accounting: bytes this op reads out of
            // another shard's writes cross the link now.
            let tb = ledger.charge(&reads[i], s, elem_bytes);
            transfer_bytes += tb;
            transfer_seconds += topo.link.seconds(tb);
            lanes[s].transfer_in_bytes += tb;
            let units = lanes[s].units;
            match decide_dataflow(b, &scope, &bufs, units) {
                DfDecision::Inline(reason) => {
                    inline_ops += 1;
                    let t0 = Instant::now();
                    match exec_chunk(&mut bufs, &job_opts, b, &scope, executed_hwm) {
                        Ok((done, ks)) => {
                            executed_hwm = executed_hwm.max(done);
                            lanes[s].busy_s += t0.elapsed().as_secs_f64();
                            lanes[s].ops += 1;
                            ledger.record(&writes[i], s);
                            slots[i] = Some(OpParallelism {
                                op: b.name.clone(),
                                dim: None,
                                range: 0,
                                workers: 1,
                                reason: format!("[{}] {reason}", lanes[s].name),
                                fork_bytes: 0,
                                merge_bytes: 0,
                                kernel_lanes: ks.vector_lanes,
                                scalar_lanes: ks.scalar_lanes,
                            });
                            for &j in &dag.succs[i] {
                                indeg[j] -= 1;
                                if indeg[j] == 0 {
                                    ready.insert(j);
                                }
                            }
                        }
                        Err(e) => failure = Some(e),
                    }
                }
                DfDecision::Offload { dim, write_ids } => {
                    let (chunks, dim_name, range) = match &dim {
                        Some((d, range)) => (
                            split_range(*range, units * OVERSUBSCRIPTION),
                            Some(d.clone()),
                            *range,
                        ),
                        None => (vec![(0u64, 0u64)], None, 0u64),
                    };
                    let chunk_blocks: Vec<Block> = match &dim_name {
                        Some(d) => chunks
                            .iter()
                            .map(|&(lo, len)| chunk_block(b, d, lo as i64, len))
                            .collect(),
                        None => vec![b.clone()],
                    };
                    let extents: Vec<Extents> = chunk_blocks
                        .iter()
                        .map(|blk| plan::flat_write_extents(blk, &scope))
                        .collect();
                    let pending = chunk_blocks.len();
                    let mut submit_err = None;
                    let mut submitted = 0usize;
                    for (c, blk) in chunk_blocks.into_iter().enumerate() {
                        let job = Job {
                            op: i,
                            chunk: c,
                            home: c % pool.size(),
                            blk,
                            scope: Arc::clone(&scope),
                            opts: job_opts.clone(),
                            local: bufs.fork(),
                            executed_base: executed_hwm,
                            reply: done_tx.clone(),
                        };
                        if let Err(e) = pool.submit(job) {
                            submit_err = Some(e);
                            break;
                        }
                        submitted += 1;
                    }
                    if submitted > 0 {
                        flights[i] = Some(Flight {
                            dim: dim_name,
                            range,
                            write_ids,
                            extents,
                            parts: (0..pending).map(|_| None).collect(),
                            pending: submitted,
                        });
                        shard_busy[s] = true;
                        op_start[i] = Some(Instant::now());
                        in_flight += 1;
                        max_in_flight = max_in_flight.max(in_flight);
                    }
                    if let Some(e) = submit_err {
                        failure = Some(e);
                    }
                }
            }
        }
        if in_flight == 0 {
            break;
        }
        // Collect one chunk completion (blocking: the scheduler owns
        // the master buffers, so merges are serialized here).
        let done = done_rx.recv().expect("scheduler holds a live sender");
        let flight = flights[done.op].as_mut().expect("completion for an in-flight op");
        match done.result {
            Ok(part) => flight.parts[done.chunk] = Some(part),
            Err(e) => {
                if failure.is_none() {
                    failure = Some(e);
                }
            }
        }
        flight.pending -= 1;
        if flight.pending > 0 {
            continue;
        }
        let flight = flights[done.op].take().unwrap();
        let s = assignment.op_shard[done.op];
        shard_busy[s] = false;
        if let Some(t0) = op_start[done.op].take() {
            lanes[s].busy_s += t0.elapsed().as_secs_f64();
        }
        in_flight -= 1;
        let complete = flight.parts.iter().all(|p| p.is_some());
        if failure.is_some() || !complete {
            for part in flight.parts.into_iter().flatten() {
                part.0.release();
            }
            if failure.is_none() {
                failure = Some(ExecError {
                    block: blocks[done.op].name.clone(),
                    message: "sharded chunk lost without a result".into(),
                });
            }
            continue;
        }
        match merge_op(&mut bufs, blocks[done.op], flight, &mut executed_hwm) {
            Ok(op) => {
                lanes[s].ops += 1;
                ledger.record(&writes[done.op], s);
                slots[done.op] = Some(op);
                for &j in &dag.succs[done.op] {
                    indeg[j] -= 1;
                    if indeg[j] == 0 {
                        ready.insert(j);
                    }
                }
            }
            Err(e) => failure = Some(e),
        }
    }

    if let Some(e) = failure {
        bufs.release();
        return Err(e);
    }
    let mut schedule = ParallelReport {
        ops: slots.into_iter().map(|s| s.expect("every op scheduled")).collect(),
        ..ParallelReport::default()
    };
    schedule.dag = Some(super::DataflowStats {
        dag_ops: n,
        edges_raw: dag.edges_raw,
        edges_war: dag.edges_war,
        edges_waw: dag.edges_waw,
        width: dag.width,
        critical_path: dag.critical_path,
        pool_size: pool.size(),
        max_in_flight,
        inline_ops,
        ..super::DataflowStats::default()
    });
    let stats = ShardStats {
        lanes,
        transfer_bytes,
        transfer_seconds,
        predicted_transfer_bytes: assignment.predicted_transfer_bytes,
        max_in_flight,
        inline_ops,
        pool_size: pool.size(),
    };
    let mut out = BTreeMap::new();
    for bdef in program.buffers_of(BufKind::Output) {
        let id = bufs.id_of(&bdef.name).unwrap();
        out.insert(bdef.name.clone(), bufs.snapshot(id));
    }
    bufs.release();
    Ok((out, ShardReport { schedule, stats, assignment }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::NullSink;
    use crate::frontend::ops;
    use crate::passes::equiv::gen_inputs;

    fn serial(p: &Program, inputs: &BTreeMap<String, Vec<f32>>) -> BTreeMap<String, Vec<f32>> {
        plan::run_program_planned(p, inputs, &ExecOptions::default(), &mut NullSink).unwrap()
    }

    #[test]
    fn cnn_is_bit_exact_on_asymmetric_pair() {
        let p = ops::cnn_program();
        let inputs = gen_inputs(&p, 53);
        let topo = ShardTopology::asymmetric_pair();
        let (out, report) =
            run_program_sharded(&p, &inputs, &topo, &ExecOptions::default()).unwrap();
        assert_eq!(serial(&p, &inputs), out, "{}", report.stats.summary_line());
        assert_eq!(report.assignment.op_shard.len(), report.schedule.ops.len());
        assert_eq!(
            report.stats.transfer_bytes, report.stats.predicted_transfer_bytes,
            "runtime transfer accounting must reproduce the static prediction: {}",
            report.stats.summary_line()
        );
    }

    #[test]
    fn pinned_round_robin_matches_serial_and_prediction() {
        let p = ops::cnn_program();
        let inputs = gen_inputs(&p, 59);
        let topo = ShardTopology::asymmetric_pair();
        let nops = p.ops().count();
        let pins: Vec<usize> = (0..nops).map(|i| i % topo.len()).collect();
        let assignment = pin_shards(&p, &topo, &pins).unwrap();
        assert!(
            assignment.predicted_transfer_bytes > 0,
            "a round-robin cut of a chain must cross the link"
        );
        let (out, report) = run_program_sharded_with(
            &p,
            &inputs,
            &topo,
            assignment,
            &ExecOptions::default(),
        )
        .unwrap();
        assert_eq!(serial(&p, &inputs), out);
        assert_eq!(report.stats.transfer_bytes, report.stats.predicted_transfer_bytes);
        assert!(report.stats.lanes.iter().all(|l| l.ops > 0), "both shards execute ops");
    }

    #[test]
    fn assign_shards_is_contiguous_and_complete() {
        let p = ops::cnn_program();
        let topo = ShardTopology::asymmetric_pair();
        let a = assign_shards(&p, &topo).unwrap();
        assert_eq!(a.op_shard.len(), p.ops().count());
        // Chain partition: shard indices never decrease in program order.
        assert!(a.op_shard.windows(2).all(|w| w[0] <= w[1]), "{:?}", a.op_shard);
        assert_eq!(a.predicted_busy.len(), 2);
    }

    #[test]
    fn pin_shards_validates_shape() {
        let p = ops::cnn_program();
        let topo = ShardTopology::asymmetric_pair();
        assert!(pin_shards(&p, &topo, &[0]).is_err(), "wrong op count");
        let nops = p.ops().count();
        assert!(pin_shards(&p, &topo, &vec![9; nops]).is_err(), "shard out of range");
    }

    #[test]
    fn ledger_charges_only_foreign_ranges() {
        let mut ledger = TransferLedger::default();
        let writes: Extents = Some(vec![(0, 0, 99)]);
        ledger.record(&writes, 0);
        // Same shard: free. Other shard: 100 elements x 4 bytes.
        assert_eq!(ledger.charge(&writes, 0, |_| 4), 0);
        assert_eq!(ledger.charge(&writes, 1, |_| 4), 400);
        // Partial overlap charges only the overlapped run.
        let half: Extents = Some(vec![(0, 50, 149)]);
        assert_eq!(ledger.charge(&half, 1, |_| 4), 200);
        // Rewriting a range from shard 1 transfers ownership.
        ledger.record(&Some(vec![(0, 0, 49)]), 1);
        assert_eq!(ledger.charge(&writes, 1, |_| 4), 200);
        // Opaque footprints and unknown buffers charge nothing.
        assert_eq!(ledger.charge(&None, 1, |_| 4), 0);
        assert_eq!(ledger.charge(&Some(vec![(7, 0, 9)]), 1, |_| 4), 0);
    }

    #[test]
    fn coalesce_merges_overlapping_refinements() {
        let merged = coalesce(&[(0, 0, 9), (0, 5, 19), (1, 0, 3), (0, 21, 30)]);
        assert_eq!(merged, vec![(0, 0, 19), (0, 21, 30), (1, 0, 3)]);
    }

    #[test]
    fn single_shard_topology_degenerates_to_dataflow() {
        let p = ops::conv_relu_program();
        let inputs = gen_inputs(&p, 61);
        let topo = ShardTopology::parse("cpu_cache").unwrap();
        let (out, report) =
            run_program_sharded(&p, &inputs, &topo, &ExecOptions::default()).unwrap();
        assert_eq!(serial(&p, &inputs), out);
        assert_eq!(report.stats.transfer_bytes, 0, "one shard never crosses a link");
        assert!((report.stats.imbalance() - 1.0).abs() < 1e-9);
    }
}
