//! Whole-program cache-line cost prediction for compiled pipelines.
//!
//! The Fig.-4 model ([`super::cacheline`]) scores *one tiling of one
//! flat block*. The pipeline autotuner needs to rank whole compiled
//! programs — arbitrary nests produced by any pass combination — so
//! this module generalizes the same model to a program tree:
//!
//! * every block that contains compute statements contributes, per
//!   non-scratch refinement, the cache lines of its rectilinear
//!   footprint over the block's own iteration space (the same
//!   `access_extent` × `footprint_lines` arithmetic as the flat model);
//! * that per-invocation figure is multiplied by the number of
//!   *distinct regions* the refinement visits: the product, along the
//!   refinement chain up to `main`, of the ranges of every enclosing
//!   block's moving indexes that appear in the chain's access
//!   polynomials. A refinement whose chain never moves (Fig. 4's
//!   untiled weights) is counted once — the "fetched once, stays
//!   resident" rule of the paper's model;
//! * block-local scratch (`RefDir::Temp`, what `localize` produces) and
//!   every view refined out of it count zero — localized traffic is the
//!   point of that pass, and the model must reward it.
//!
//! On a flat-then-tiled single block this reproduces `tiling_cost`'s
//! `total_lines` exactly (`tiles × tiled lines + untiled lines`); the
//! unit tests pin that equivalence. The model has *no capacity term* —
//! it ranks pipelines that all tile against the same memory unit, and
//! the tuner's simulation stage re-scores the leaders with real cache
//! geometry, which is where capacity pressure shows up.

use std::collections::BTreeMap;

use crate::ir::{Block, Program, RefDir, Statement};

use super::cacheline::{access_extent, footprint_lines};

/// Aggregate prediction for one compiled program.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProgramCost {
    /// Predicted cache lines touched over the whole execution.
    pub lines: u64,
    /// Leaf compute iterations (constraint-respecting lattice points,
    /// summed over compute blocks × their invocation counts).
    pub leaf_iterations: u64,
}

impl ProgramCost {
    /// Lines per compute iteration — the Fig.-4 figure of merit lifted
    /// to a whole program (lower is better).
    pub fn lines_per_iteration(&self) -> f64 {
        if self.leaf_iterations == 0 {
            return f64::INFINITY;
        }
        self.lines as f64 / self.leaf_iterations as f64
    }
}

/// Does this block directly execute scalar work (as opposed to only
/// nesting child blocks)?
fn has_compute(b: &Block) -> bool {
    b.stmts.iter().any(|s| {
        matches!(
            s,
            Statement::Load { .. }
                | Statement::Store { .. }
                | Statement::Intrinsic { .. }
                | Statement::Constant { .. }
                | Statement::Special(_)
        )
    })
}

/// Number of distinct view origins `access` takes as the block's moving
/// ranged indexes sweep: the product of the ranges of every moving
/// index with a nonzero coefficient in any access dimension.
/// (Constraints are ignored — an over-approximation consistent with the
/// Fig.-4 model's "overflow accesses still cost".)
fn motion(access: &[crate::poly::Affine], b: &Block) -> u64 {
    let mut m: u64 = 1;
    for idx in &b.idxs {
        if idx.affine.is_some() || idx.range <= 1 {
            continue;
        }
        if access.iter().any(|a| a.coeff(&idx.name) != 0) {
            m = m.saturating_mul(idx.range);
        }
    }
    m
}

/// Line-granularity correction for a moving refinement of a structural
/// block. `m` distinct view origins each re-fetch their footprint —
/// the Fig.-4 rule — *except* when the sweep is a perfect disjoint
/// cover of its union box (tiles without halo, fusion's per-point
/// slices): one pass over the union then, so the effective region
/// count is `union lines / per-region lines`. Without this, a fused
/// sweep of N contiguous scalars would cost N whole lines instead of
/// N/line.
fn effective_regions(r: &crate::ir::Refinement, b: &Block, m: u64, line_bytes: u64) -> u64 {
    if m <= 1 {
        return m;
    }
    let full: BTreeMap<String, u64> = b.idxs.iter().map(|i| (i.name.clone(), i.range)).collect();
    let sizes: Vec<u64> = r.ttype.dims.iter().map(|d| d.size.max(1)).collect();
    let union: Vec<u64> = r
        .access
        .iter()
        .zip(&sizes)
        .map(|(a, s)| access_extent(a, &full).saturating_add(s - 1))
        .collect();
    let vol_sizes = sizes.iter().copied().fold(1u64, |a, e| a.saturating_mul(e));
    let vol_regions = m.saturating_mul(vol_sizes);
    let vol_union: u64 = union.iter().copied().fold(1u64, |a, e| a.saturating_mul(e));
    if vol_regions != vol_union {
        return m; // halo overlap or sparse sweep: re-fetch per region
    }
    let line_elems = (line_bytes / r.ttype.dtype.size_bytes()).max(1);
    let per = footprint_lines(&sizes, &r.ttype.strides(), line_elems).max(1);
    let un = footprint_lines(&union, &r.ttype.strides(), line_elems);
    un.div_ceil(per).max(1)
}

/// Recursive walk. `execs` is how many times `b`'s body runs (product
/// of the ancestors' iteration counts); `regions` maps refinement
/// names *in `b`'s parent scope* to the number of distinct line-level
/// regions that name visits (0 = scratch-backed, free).
fn walk(
    b: &Block,
    execs: u64,
    regions: &BTreeMap<String, u64>,
    line_bytes: u64,
    total: &mut ProgramCost,
) {
    if has_compute(b) {
        let full: BTreeMap<String, u64> =
            b.idxs.iter().map(|i| (i.name.clone(), i.range)).collect();
        for r in &b.refs {
            if r.dir == RefDir::Temp {
                continue;
            }
            let m = *regions.get(&r.from).unwrap_or(&1);
            if m == 0 {
                continue; // backed by block-local scratch somewhere up the chain
            }
            let extents: Vec<u64> = r.access.iter().map(|a| access_extent(a, &full)).collect();
            let line_elems = (line_bytes / r.ttype.dtype.size_bytes()).max(1);
            let lines = footprint_lines(&extents, &r.ttype.strides(), line_elems);
            total.lines = total.lines.saturating_add(lines.saturating_mul(m));
        }
        total.leaf_iterations =
            total.leaf_iterations.saturating_add(b.iterations().saturating_mul(execs));
    }
    // Region counts for the child scopes: chain multiplier × this
    // block's own (line-corrected) motion of each refinement.
    let mut child_regions: BTreeMap<String, u64> = BTreeMap::new();
    for r in &b.refs {
        let m = if r.dir == RefDir::Temp {
            0
        } else {
            let parent = regions.get(&r.from).copied().unwrap_or(1);
            let own = motion(&r.access, b);
            parent.saturating_mul(effective_regions(r, b, own, line_bytes))
        };
        child_regions.insert(r.into.clone(), m);
    }
    let child_execs = execs.saturating_mul(b.iterations().max(1));
    for c in b.child_blocks() {
        walk(c, child_execs, &child_regions, line_bytes, total);
    }
}

/// Predict the cache-line traffic of a compiled program against a
/// memory unit with the given line size (bytes). Element sizes come
/// from each refinement's dtype.
pub fn predicted_program_cost(p: &Program, line_bytes: u64) -> ProgramCost {
    let mut total = ProgramCost::default();
    // `main`'s refinements all map whole program buffers (temps
    // included — between-op intermediates are real memory): one region
    // each.
    let top: BTreeMap<String, u64> = p.main.refs.iter().map(|r| (r.into.clone(), 1)).collect();
    for op in p.ops() {
        walk(op, 1, &top, line_bytes.max(1), &mut total);
    }
    total
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;
    use crate::cost::cacheline::{tiling_cost, CostParams};
    use crate::frontend::ops;
    use crate::ir::Statement;
    use crate::passes::tile::{apply_tiling, TileOptions};

    /// On a flat single-block program the prediction equals the Fig.-4
    /// model's total lines for the untiled "tiling".
    #[test]
    fn flat_program_matches_cacheline_model() {
        let p = ops::fig4_conv_program();
        let Statement::Block(b) = &p.main.stmts[0] else { panic!() };
        let flat = tiling_cost(b, &BTreeMap::new(), &CostParams::default());
        let c = predicted_program_cost(&p, 8);
        assert_eq!(c.lines, flat.total_lines, "flat lines must match tiling_cost");
        assert_eq!(c.leaf_iterations, b.iterations());
    }

    /// After tiling, the prediction equals `tiles × tiled lines +
    /// untiled lines` — the exact Fig.-4(b) arithmetic (1008 lines for
    /// the 3×4 tile).
    #[test]
    fn tiled_program_matches_fig4b_total() {
        let mut p = ops::fig4_conv_program();
        let Statement::Block(b) = &mut p.main.stmts[0] else { panic!() };
        let tile: BTreeMap<String, u64> =
            [("x".to_string(), 3u64), ("y".to_string(), 4)].into();
        let flat = (**b).clone();
        let cost = tiling_cost(&flat, &tile, &CostParams::default());
        **b = apply_tiling(&flat, &tile, &TileOptions::default());
        let c = predicted_program_cost(&p, 8);
        assert_eq!(c.lines, cost.total_lines, "nested prediction must match Fig. 4");
        assert_eq!(c.lines, 1008);
    }

    /// The untiled-weights residency rule: weights whose chain never
    /// moves are counted once, so a better tiling strictly lowers the
    /// predicted lines.
    #[test]
    fn better_tilings_predict_fewer_lines() {
        let mk = |tx: u64, ty: u64| {
            let mut p = ops::fig4_conv_program();
            let Statement::Block(b) = &mut p.main.stmts[0] else { panic!() };
            let tile: BTreeMap<String, u64> =
                [("x".to_string(), tx), ("y".to_string(), ty)].into();
            **b = apply_tiling(b, &tile, &TileOptions::default());
            predicted_program_cost(&p, 8).lines
        };
        // 3×4 is the Fig.-4 sweet spot; 1×1 thrashes halos.
        assert!(mk(3, 4) < mk(1, 1), "{} vs {}", mk(3, 4), mk(1, 1));
    }

    /// Multi-op programs accumulate per-op traffic and iteration counts.
    #[test]
    fn cnn_program_accumulates_all_ops() {
        let p = ops::cnn_program();
        let c = predicted_program_cost(&p, 64);
        assert!(c.lines > 0);
        assert!(c.leaf_iterations > 0);
        assert!(c.lines_per_iteration().is_finite());
        // Per-op sum: dropping an op strictly reduces the prediction.
        let mut q = p.clone();
        q.main.stmts.pop();
        let cq = predicted_program_cost(&q, 64);
        assert!(cq.lines < c.lines);
    }

    /// Localized scratch is free: a compiled pipeline with `localize`
    /// never predicts more lines than the same pipeline without it.
    #[test]
    fn localization_never_increases_predicted_lines() {
        use crate::hw::{targets, PassConfig};
        let p = ops::cnn_program();
        let base = targets::cpu_cache();
        let with = crate::passes::compile(&p, &base, false).unwrap();
        let mut nl = base.clone();
        nl.passes.retain(|pc| !matches!(pc, PassConfig::Localize));
        let without = crate::passes::compile(&p, &nl, false).unwrap();
        let lw = predicted_program_cost(&with.program, 64).lines;
        let lo = predicted_program_cost(&without.program, 64).lines;
        assert!(lw <= lo, "localize must not raise the prediction ({lw} vs {lo})");
    }
}
