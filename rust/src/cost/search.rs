//! Tile-size search for the autotiling pass.
//!
//! §3.3: "The autotiling optimization for Stripe explores a space of
//! tile sizes using a cost function ... Search-space heuristics, such as
//! only considering power-of-2 dimensions to optionally improve compile
//! performance, may also constrain the tile sizes considered."

use std::collections::BTreeMap;

use crate::ir::Block;

use super::cacheline::{tiling_cost_cached, CostParams, TileCost};

/// Candidate-generation strategy per index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchSpace {
    /// All sizes 1..=range.
    Exhaustive,
    /// Powers of two ≤ range, plus the full range.
    PowersOfTwo,
    /// Divisors of the range (no overflow tiles).
    Divisors,
}

impl SearchSpace {
    /// Short name used in pipeline descriptions and tuning labels.
    pub fn name(self) -> &'static str {
        match self {
            SearchSpace::Exhaustive => "exhaustive",
            SearchSpace::PowersOfTwo => "pow2",
            SearchSpace::Divisors => "divisors",
        }
    }

    /// Tile-size candidates for an index of the given range, ascending.
    /// A degenerate range of 0 yields no candidates for every strategy
    /// (a tile size of 0 is never a valid split).
    pub fn candidates(self, range: u64) -> Vec<u64> {
        if range == 0 {
            return Vec::new();
        }
        match self {
            SearchSpace::Exhaustive => (1..=range).collect(),
            SearchSpace::PowersOfTwo => {
                let mut v: Vec<u64> = (0..)
                    .map(|k| 1u64 << k)
                    .take_while(|&p| p <= range)
                    .collect();
                if !v.contains(&range) {
                    v.push(range);
                }
                v
            }
            SearchSpace::Divisors => (1..=range).filter(|d| range % d == 0).collect(),
        }
    }
}

/// Search telemetry. Aggregated across blocks by the autotile pass
/// (one [`PassReport`](crate::passes::PassReport) carries the sum over
/// every block it searched) and surfaced by the compiled-network
/// summary and `stripe run`.
#[derive(Debug, Clone, Copy, Default)]
pub struct SearchStats {
    pub evaluated: usize,
    pub feasible: usize,
}

impl SearchStats {
    /// Fold another search's counters into this one.
    pub fn absorb(&mut self, other: &SearchStats) {
        self.evaluated += other.evaluated;
        self.feasible += other.feasible;
    }

    /// The one-line rendering shared by `stripe run`, `stripe tune`,
    /// and the compiled-network summary.
    pub fn summary_line(&self) -> String {
        format!(
            "autotile search: {} tiling(s) evaluated, {} feasible",
            self.evaluated, self.feasible
        )
    }
}

/// Find the lowest-cost feasible tiling over `tileable` indexes.
///
/// Additional constraints honored (per §3.3):
/// * `multiple_of`: tile sizes must be even multiples of earlier
///   vectorization/tensorization block sizes;
/// * a tiling must actually tile something (at least one tensor
///   footprint shrinks) when a memory cap is in force;
/// * a combinatorial budget caps the explored space.
pub fn best_tiling(
    block: &Block,
    tileable: &[String],
    params: &CostParams,
    space: SearchSpace,
    multiple_of: &BTreeMap<String, u64>,
    budget: usize,
) -> (Option<TileCost>, SearchStats) {
    let mut stats = SearchStats::default();
    // Per-index candidate lists.
    let mut cand: Vec<(String, Vec<u64>)> = Vec::new();
    for name in tileable {
        let Some(idx) = block.idx(name) else { continue };
        let m = *multiple_of.get(name).unwrap_or(&1);
        let mut c: Vec<u64> =
            space.candidates(idx.range).into_iter().filter(|t| t % m == 0).collect();
        if c.is_empty() {
            c.push(idx.range);
        }
        cand.push((name.clone(), c));
    }
    if cand.is_empty() {
        return (None, stats);
    }

    // MACs are tiling-independent; enumerate the iteration space once.
    let macs = block.iterations();
    let mut best: Option<TileCost> = None;
    let mut counters = vec![0usize; cand.len()];
    'outer: loop {
        if stats.evaluated >= budget {
            break;
        }
        let tile: BTreeMap<String, u64> = cand
            .iter()
            .zip(&counters)
            .map(|((n, cs), &k)| (n.clone(), cs[k]))
            .collect();
        let tc = tiling_cost_cached(block, &tile, params, Some(macs));
        stats.evaluated += 1;
        // Require real tiling when a cap exists (Fig. 4's premise is that
        // the whole operation does not fit in local memory).
        let actually_tiled = tc.tile_mem_elems > 0;
        if tc.feasible && actually_tiled {
            stats.feasible += 1;
            let better = match &best {
                None => true,
                Some(b) => tc.cost() < b.cost(),
            };
            if better {
                best = Some(tc);
            }
        }
        // Advance odometer.
        let mut k = cand.len();
        loop {
            if k == 0 {
                break 'outer;
            }
            k -= 1;
            counters[k] += 1;
            if counters[k] < cand[k].1.len() {
                break;
            }
            counters[k] = 0;
        }
    }
    (best, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::fig5_conv_block;

    #[test]
    fn candidate_spaces() {
        assert_eq!(SearchSpace::Exhaustive.candidates(4), vec![1, 2, 3, 4]);
        assert_eq!(SearchSpace::PowersOfTwo.candidates(12), vec![1, 2, 4, 8, 12]);
        assert_eq!(SearchSpace::Divisors.candidates(12), vec![1, 2, 3, 4, 6, 12]);
    }

    #[test]
    fn candidates_for_degenerate_range_zero_are_empty() {
        // No strategy may ever propose a 0-sized tile.
        for space in [SearchSpace::Exhaustive, SearchSpace::PowersOfTwo, SearchSpace::Divisors] {
            assert!(space.candidates(0).is_empty(), "{space:?}");
        }
    }

    #[test]
    fn candidates_for_range_one_are_the_identity_tile() {
        for space in [SearchSpace::Exhaustive, SearchSpace::PowersOfTwo, SearchSpace::Divisors] {
            assert_eq!(space.candidates(1), vec![1], "{space:?}");
        }
    }

    #[test]
    fn pow2_candidates_on_non_pow2_ranges_include_the_full_range() {
        // The full range rides along so "no tiling" stays reachable.
        assert_eq!(SearchSpace::PowersOfTwo.candidates(7), vec![1, 2, 4, 7]);
        assert_eq!(SearchSpace::PowersOfTwo.candidates(9), vec![1, 2, 4, 8, 9]);
        // Exact powers of two are not duplicated.
        assert_eq!(SearchSpace::PowersOfTwo.candidates(8), vec![1, 2, 4, 8]);
        // Candidates are sorted ascending, unique, and end at the full
        // range.
        for r in 1..64u64 {
            let c = SearchSpace::PowersOfTwo.candidates(r);
            assert!(c.windows(2).all(|w| w[0] < w[1]), "range {r}: {c:?}");
            assert_eq!(*c.last().unwrap(), r);
        }
    }

    #[test]
    fn divisor_candidates_are_complete_and_valid() {
        for r in 1..=96u64 {
            let c = SearchSpace::Divisors.candidates(r);
            // Every candidate divides; every divisor is present.
            assert!(c.iter().all(|d| r % d == 0), "range {r}: {c:?}");
            for d in 1..=r {
                assert_eq!(c.contains(&d), r % d == 0, "range {r} divisor {d}");
            }
            // 1 and r always present, sorted ascending.
            assert_eq!(c.first(), Some(&1));
            assert_eq!(c.last(), Some(&r));
            assert!(c.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn search_finds_feasible_minimum() {
        let b = fig5_conv_block();
        let (best, stats) = best_tiling(
            &b,
            &["x".to_string(), "y".to_string()],
            &CostParams::default(),
            SearchSpace::Exhaustive,
            &BTreeMap::new(),
            100_000,
        );
        let best = best.expect("feasible tiling exists");
        assert!(stats.evaluated == 12 * 16);
        assert!(best.feasible);
        assert!(best.tile_mem_elems <= 512);
        // The winner must beat the degenerate 1×1 tiling.
        let one = crate::cost::cacheline::tiling_cost(
            &b,
            &[("x".to_string(), 1), ("y".to_string(), 1)].into(),
            &CostParams::default(),
        );
        assert!(best.cost() <= one.cost());
    }

    #[test]
    fn pow2_heuristic_evaluates_fewer() {
        let b = fig5_conv_block();
        let (_, ex) = best_tiling(
            &b,
            &["x".to_string(), "y".to_string()],
            &CostParams::default(),
            SearchSpace::Exhaustive,
            &BTreeMap::new(),
            100_000,
        );
        let (best, p2) = best_tiling(
            &b,
            &["x".to_string(), "y".to_string()],
            &CostParams::default(),
            SearchSpace::PowersOfTwo,
            &BTreeMap::new(),
            100_000,
        );
        assert!(p2.evaluated < ex.evaluated);
        assert!(best.is_some());
    }

    #[test]
    fn multiple_of_constraint_respected() {
        let b = fig5_conv_block();
        let mult: BTreeMap<String, u64> = [("y".to_string(), 4)].into();
        let (best, _) = best_tiling(
            &b,
            &["x".to_string(), "y".to_string()],
            &CostParams::default(),
            SearchSpace::Exhaustive,
            &mult,
            100_000,
        );
        let best = best.unwrap();
        assert_eq!(best.tile["y"] % 4, 0);
    }

    #[test]
    fn budget_caps_search() {
        let b = fig5_conv_block();
        let (_, stats) = best_tiling(
            &b,
            &["x".to_string(), "y".to_string()],
            &CostParams::default(),
            SearchSpace::Exhaustive,
            &BTreeMap::new(),
            10,
        );
        assert_eq!(stats.evaluated, 10);
    }
}
