//! The Fig.-4 cache-line cost model.
//!
//! For a proposed tiling of a flat contraction block, compute:
//!
//! * the rectilinear *footprint* of each tensor per tile — extents per
//!   dimension derived from the affine access coefficients, **including
//!   overflow** ("accesses to these elements are removed by constraints
//!   in execution but still increase the cost");
//! * cache lines per tile per tensor, assuming line-aligned tiles (the
//!   paper's layouts make the innermost dimension line-multiple);
//! * MACs = lattice points of the *original* iteration space (honoring
//!   halo constraints — out-of-bounds positions do no work);
//! * cost = total lines / total MACs;
//! * feasibility: Σ footprints of tiled tensors ≤ the memory cap
//!   (untiled tensors — e.g. Fig. 4's weights — are exempt).

use std::collections::BTreeMap;

use crate::ir::{Block, RefDir};
use crate::util::div_ceil;

/// Model parameters (Fig. 4 uses line=8 elements, cap=512 elements).
#[derive(Debug, Clone, Copy)]
pub struct CostParams {
    pub line_elems: u64,
    pub mem_cap_elems: u64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams { line_elems: 8, mem_cap_elems: 512 }
    }
}

/// Result of evaluating one tiling.
#[derive(Debug, Clone)]
pub struct TileCost {
    /// Tile shape evaluated (per index).
    pub tile: BTreeMap<String, u64>,
    /// Lines touched per tile, per tensor (refinement `into` name).
    pub lines_per_tile: Vec<(String, u64)>,
    /// Footprint elements per tile, per tensor.
    pub footprint_elems: Vec<(String, u64)>,
    /// Number of tiles (product of per-index quotients, rounded up).
    pub tiles: u64,
    /// Total lines = tiles × Σ lines-per-tile.
    pub total_lines: u64,
    /// Valid multiply-accumulates (constraint-respecting lattice points).
    pub macs: u64,
    /// Memory used by tiled tensors' footprints (cap check).
    pub tile_mem_elems: u64,
    /// Whether the tiling satisfies the memory cap.
    pub feasible: bool,
}

impl TileCost {
    /// The paper's figure of merit: cache lines per MAC (lower better).
    pub fn cost(&self) -> f64 {
        if self.macs == 0 {
            return f64::INFINITY;
        }
        self.total_lines as f64 / self.macs as f64
    }
}

/// Per-dimension footprint extent of an access under a tiling: for
/// access `Σ c_i·x_i + k` with index `x_i` restricted to a tile of
/// `t_i` consecutive values, the extent is `Σ |c_i|·(t_i − 1) + 1`.
pub fn access_extent(access: &crate::poly::Affine, tile: &BTreeMap<String, u64>) -> u64 {
    let mut span = 0i64;
    for (name, coeff) in access.terms() {
        let t = *tile.get(name).unwrap_or(&1);
        span += coeff.abs() * (t as i64 - 1);
    }
    (span + 1) as u64
}

/// Lines touched by one rectilinear footprint, assuming the innermost
/// (stride-1) dimension starts line-aligned: product of outer extents ×
/// ⌈inner extent / line⌉. Dimensions with non-unit stride each start a
/// new line (conservative; exact for the paper's layouts).
pub fn footprint_lines(extents: &[u64], strides: &[i64], line_elems: u64) -> u64 {
    let mut lines: u64 = 1;
    for (d, (&e, &s)) in extents.iter().zip(strides).enumerate() {
        let innermost = d == extents.len() - 1;
        if innermost && s == 1 {
            lines *= div_ceil(e as i64, line_elems as i64) as u64;
        } else if s.unsigned_abs() < line_elems && s != 0 {
            // Sub-line stride: consecutive positions share lines.
            lines *= div_ceil((e as i64 - 1) * s.abs() + 1, line_elems as i64) as u64;
        } else {
            lines *= e;
        }
    }
    lines
}

/// Evaluate one tiling of a flat contraction block. `tile` maps each
/// index name to its inner (tile) range; missing names default to the
/// full range (untiled).
pub fn tiling_cost(block: &Block, tile: &BTreeMap<String, u64>, params: &CostParams) -> TileCost {
    tiling_cost_cached(block, tile, params, None)
}

/// Like [`tiling_cost`] but with a precomputed MAC count (the MAC count
/// does not depend on the tiling; searches compute it once).
pub fn tiling_cost_cached(
    block: &Block,
    tile: &BTreeMap<String, u64>,
    params: &CostParams,
    macs_hint: Option<u64>,
) -> TileCost {
    // Effective tile: full range for unmentioned indexes.
    let mut eff: BTreeMap<String, u64> = BTreeMap::new();
    let mut tiles: u64 = 1;
    for idx in &block.idxs {
        let t = (*tile.get(&idx.name).unwrap_or(&idx.range)).clamp(1, idx.range.max(1));
        tiles *= div_ceil(idx.range as i64, t as i64) as u64;
        eff.insert(idx.name.clone(), t);
    }

    let full: BTreeMap<String, u64> =
        block.idxs.iter().map(|i| (i.name.clone(), i.range)).collect();
    let mut lines_per_tile = Vec::new();
    let mut footprint_elems = Vec::new();
    let mut tile_mem = 0u64;
    let mut tiled_lines = 0u64;
    let mut untiled_lines = 0u64;
    for r in &block.refs {
        if r.dir == RefDir::Temp {
            continue;
        }
        let extents: Vec<u64> = r.access.iter().map(|a| access_extent(a, &eff)).collect();
        let full_extents: Vec<u64> =
            r.access.iter().map(|a| access_extent(a, &full)).collect();
        let elems: u64 = extents.iter().product();
        let lines = footprint_lines(&extents, &r.ttype.strides(), params.line_elems);
        // A tensor is "tiled" if any extent shrank vs the untiled run.
        // Tiled tensors are re-fetched per tile; untiled tensors (the
        // Fig.-4 weights) are fetched once and exempt from the cap.
        let tiled = extents != full_extents;
        if tiled {
            tile_mem += elems;
            tiled_lines += lines;
        } else {
            untiled_lines += lines;
        }
        lines_per_tile.push((r.into.clone(), lines));
        footprint_elems.push((r.into.clone(), elems));
    }

    let total_lines = tiles * tiled_lines + untiled_lines;
    let macs = macs_hint.unwrap_or_else(|| block.iterations());
    TileCost {
        tile: eff,
        lines_per_tile,
        footprint_elems,
        tiles,
        total_lines,
        macs,
        tile_mem_elems: tile_mem,
        feasible: tile_mem <= params.mem_cap_elems,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::fig5_conv_block;

    fn tile(pairs: &[(&str, u64)]) -> BTreeMap<String, u64> {
        pairs.iter().map(|(n, v)| (n.to_string(), *v)).collect()
    }

    /// The Fig.-4(b) tiling: 3×4 output tile.
    #[test]
    fn fig4b_tiling_cost() {
        let b = fig5_conv_block();
        let c = tiling_cost(&b, &tile(&[("x", 3), ("y", 4)]), &CostParams::default());
        // Input footprint per tile: (3+2)×(4+2)×8 = 240 elems, 30 lines.
        // Output: 3×4×16 = 192 elems, 24 lines. Weights: 3×3×16×8 = 1152
        // elems, 144 lines (untiled → exempt from the cap).
        let lines: BTreeMap<&str, u64> =
            c.lines_per_tile.iter().map(|(n, l)| (n.as_str(), *l)).collect();
        assert_eq!(lines["I"], 30);
        assert_eq!(lines["O"], 24);
        assert_eq!(lines["F"], 144);
        assert_eq!(c.tiles, 4 * 4);
        assert_eq!(c.tile_mem_elems, 240 + 192);
        assert!(c.feasible);
        // MACs: valid (x,i) pairs 34, (y,j) pairs 46, ×8×16.
        assert_eq!(c.macs, 34 * 46 * 8 * 16);
        // Tiled tensors (I, O) are fetched per tile; the untiled weights
        // once: 16 × (30 + 24) + 144.
        assert_eq!(c.total_lines, 16 * (30 + 24) + 144);
        assert!((c.cost() - 1008.0 / 200_192.0).abs() < 1e-12);
    }

    /// Untiled: single "tile" covering everything — infeasible under the
    /// 512-element cap.
    #[test]
    fn untiled_is_infeasible_under_cap() {
        let b = fig5_conv_block();
        let c = tiling_cost(&b, &BTreeMap::new(), &CostParams::default());
        assert_eq!(c.tiles, 1);
        assert_eq!(c.tile_mem_elems, 0); // nothing shrank ⇒ nothing "tiled"
        // With no tensor tiled the cap is trivially satisfied; the
        // search layer requires at least one tiled tensor when a cap is
        // set (tested in search.rs).
        assert!(c.feasible);
    }

    /// Degenerate thin tiles pay halo overhead: 1×16 tile reads
    /// (1+2)×(16+2) input elements for 1×16 outputs.
    #[test]
    fn thin_tiles_cost_more_than_square() {
        let b = fig5_conv_block();
        let p = CostParams::default();
        let square = tiling_cost(&b, &tile(&[("x", 3), ("y", 4)]), &p);
        let thin = tiling_cost(&b, &tile(&[("x", 1), ("y", 8)]), &p);
        assert!(thin.feasible);
        assert!(thin.cost() > square.cost(), "{} vs {}", thin.cost(), square.cost());
    }

    /// Tiles that do not divide evenly produce overflow tiles (rounded-up
    /// quotient), still counted in lines.
    #[test]
    fn uneven_tiles_round_up() {
        let b = fig5_conv_block();
        let c = tiling_cost(&b, &tile(&[("x", 5), ("y", 6)]), &CostParams::default());
        assert_eq!(c.tiles, 3 * 3); // ceil(12/5)=3, ceil(16/6)=3
    }

    #[test]
    fn access_extent_math() {
        use crate::poly::Affine;
        let a = Affine::from_terms(&[("x", 1), ("i", 1)], -1);
        let t = tile(&[("x", 3), ("i", 3)]);
        assert_eq!(access_extent(&a, &t), 5); // (3-1)+(3-1)+1
        let b = Affine::from_terms(&[("x", 3)], 0);
        assert_eq!(access_extent(&b, &tile(&[("x", 4)])), 10); // 3*(4-1)+1
    }

    #[test]
    fn footprint_lines_alignment() {
        // (5,6,8) footprint, strides (128,8,1), line 8 → 5*6*1 = 30.
        assert_eq!(footprint_lines(&[5, 6, 8], &[128, 8, 1], 8), 30);
        // (3,4,16): 16 elems of stride 1 = 2 lines → 24.
        assert_eq!(footprint_lines(&[3, 4, 16], &[256, 16, 1], 8), 24);
        // Sub-line stride in a middle dim: (2,2) strides (4,1), line 8 →
        // rows 0..4+2 fit one line: dim0 extent spans (2-1)*4+1=5 elems
        // → 1 line × ceil(2/8)=1 → 1.
        assert_eq!(footprint_lines(&[2, 2], &[4, 1], 8), 1);
    }
}
