//! Roofline model (Williams, Waterman & Patterson — cited as [33] by the
//! paper): attainable performance = min(peak compute, AI × bandwidth).
//!
//! Used by the TPU-style hardware targets where the resource of interest
//! is bytes moved between HBM and VMEM rather than cache lines, and for
//! the §Perf efficiency-ratio bookkeeping in EXPERIMENTS.md.

/// Machine balance parameters.
#[derive(Debug, Clone, Copy)]
pub struct MachineRoof {
    /// Peak floating-point throughput (FLOP/s).
    pub peak_flops: f64,
    /// Sustained memory bandwidth (bytes/s) at the level of interest.
    pub mem_bw: f64,
}

impl MachineRoof {
    /// Arithmetic intensity at which compute and memory balance.
    pub fn ridge_point(&self) -> f64 {
        self.peak_flops / self.mem_bw
    }

    /// Attainable FLOP/s at a given arithmetic intensity (FLOP/byte).
    pub fn attainable(&self, ai: f64) -> f64 {
        (ai * self.mem_bw).min(self.peak_flops)
    }
}

/// Roofline estimate for one kernel/workload.
#[derive(Debug, Clone, Copy)]
pub struct RooflineEstimate {
    pub flops: f64,
    pub bytes: f64,
    pub ai: f64,
    /// Attainable FLOP/s under the roof.
    pub attainable_flops: f64,
    /// Lower-bound execution time (s).
    pub min_time: f64,
    /// True if the kernel is memory-bound at this AI.
    pub memory_bound: bool,
}

/// Estimate the roofline position of a workload with `flops` total
/// floating-point operations moving `bytes` total bytes.
pub fn estimate(flops: f64, bytes: f64, roof: &MachineRoof) -> RooflineEstimate {
    let ai = if bytes > 0.0 { flops / bytes } else { f64::INFINITY };
    let attainable = roof.attainable(ai);
    RooflineEstimate {
        flops,
        bytes,
        ai,
        attainable_flops: attainable,
        min_time: (flops / roof.peak_flops).max(bytes / roof.mem_bw),
        memory_bound: ai < roof.ridge_point(),
    }
}

/// Efficiency of a measured run vs the roofline bound (0..1].
pub fn efficiency(measured_time: f64, est: &RooflineEstimate) -> f64 {
    if measured_time <= 0.0 {
        return 0.0;
    }
    est.min_time / measured_time
}

#[cfg(test)]
mod tests {
    use super::*;

    const ROOF: MachineRoof = MachineRoof { peak_flops: 1e12, mem_bw: 1e11 };

    #[test]
    fn ridge_point_balance() {
        assert!((ROOF.ridge_point() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn memory_bound_below_ridge() {
        let e = estimate(1e9, 1e9, &ROOF); // AI = 1 < 10
        assert!(e.memory_bound);
        assert!((e.attainable_flops - 1e11).abs() / 1e11 < 1e-9);
        // Time dominated by bytes: 1e9/1e11 = 0.01 s
        assert!((e.min_time - 0.01).abs() < 1e-12);
    }

    #[test]
    fn compute_bound_above_ridge() {
        let e = estimate(1e12, 1e9, &ROOF); // AI = 1000 > 10
        assert!(!e.memory_bound);
        assert!((e.attainable_flops - 1e12).abs() / 1e12 < 1e-9);
        assert!((e.min_time - 1.0).abs() < 1e-9);
    }

    #[test]
    fn efficiency_ratio() {
        let e = estimate(1e12, 1e9, &ROOF);
        assert!((efficiency(2.0, &e) - 0.5).abs() < 1e-9);
        assert_eq!(efficiency(0.0, &e), 0.0);
    }
}
