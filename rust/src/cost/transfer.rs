//! Transfer-cost model for shard boundaries (heterogeneous sharding).
//!
//! When one network is split across multiple simulated targets
//! (`hw::shard::ShardTopology`, executed by `exec::shard`), bytes that
//! one shard reads out of another shard's writes cross the inter-shard
//! link. This module prices those crossings: a [`LinkModel`] turns a
//! byte count into seconds (fixed per-hop latency plus bytes over
//! bandwidth), and the helpers fold per-shard busy times and the
//! transfer term into the makespan/imbalance figures the shard
//! assignment search and the bench report use.
//!
//! Tiramisu's distributed/communication layer is the reference: the
//! transfer term is explicit in the schedule's cost, never an
//! afterthought of the memory model.

/// Default inter-shard link bandwidth: 16 GB/s, roughly a PCIe-gen4
/// x16 interconnect — deliberately far below every built-in target's
/// local `mem_bw`, so a bad cut is visibly punished.
pub const DEFAULT_LINK_BANDWIDTH: f64 = 16.0e9;

/// Default per-hop transfer latency (DMA setup / doorbell cost).
pub const DEFAULT_LINK_LATENCY_S: f64 = 2.0e-6;

/// An inter-shard interconnect: every byte a shard reads out of
/// another shard's writes is charged `latency + bytes / bandwidth`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Link bandwidth in bytes per second.
    pub bandwidth: f64,
    /// Fixed per-hop latency in seconds, charged once per non-empty
    /// transfer.
    pub latency_s: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel { bandwidth: DEFAULT_LINK_BANDWIDTH, latency_s: DEFAULT_LINK_LATENCY_S }
    }
}

impl LinkModel {
    /// A link with the given bandwidth in gigabytes per second and the
    /// default hop latency.
    pub fn with_gbps(gbps: f64) -> LinkModel {
        LinkModel { bandwidth: (gbps * 1e9).max(1.0), ..LinkModel::default() }
    }

    /// Modeled seconds to move `bytes` across the link (0 for 0 bytes —
    /// no hop happens at all).
    pub fn seconds(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.latency_s + bytes as f64 / self.bandwidth.max(1.0)
    }
}

/// Load imbalance across shard busy times: `max / mean`, so 1.0 is a
/// perfectly balanced schedule and 2.0 means the busiest shard carries
/// twice the average. Degenerate inputs (no shards, all idle) report
/// 1.0 — "nothing to balance".
pub fn imbalance(busy: &[f64]) -> f64 {
    if busy.is_empty() {
        return 1.0;
    }
    let max = busy.iter().copied().fold(0.0f64, f64::max);
    let mean = busy.iter().sum::<f64>() / busy.len() as f64;
    if mean <= 0.0 {
        return 1.0;
    }
    max / mean
}

/// Modeled makespan of a sharded schedule: the busiest shard's compute
/// time plus the (serialized, worst-case) transfer term. The shard
/// assignment search minimizes this.
pub fn makespan(busy: &[f64], transfer_s: f64) -> f64 {
    busy.iter().copied().fold(0.0f64, f64::max) + transfer_s.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bytes_cost_nothing() {
        let link = LinkModel::default();
        assert_eq!(link.seconds(0), 0.0);
        assert!(link.seconds(1) > 0.0);
    }

    #[test]
    fn seconds_scale_with_bytes_over_bandwidth() {
        let link = LinkModel { bandwidth: 1e9, latency_s: 0.0 };
        assert!((link.seconds(1_000_000_000) - 1.0).abs() < 1e-12);
        let faster = LinkModel { bandwidth: 2e9, latency_s: 0.0 };
        assert!(faster.seconds(1_000_000_000) < link.seconds(1_000_000_000));
    }

    #[test]
    fn with_gbps_sets_bandwidth() {
        let link = LinkModel::with_gbps(32.0);
        assert!((link.bandwidth - 32.0e9).abs() < 1.0);
    }

    #[test]
    fn imbalance_is_max_over_mean() {
        assert_eq!(imbalance(&[]), 1.0);
        assert_eq!(imbalance(&[0.0, 0.0]), 1.0);
        assert!((imbalance(&[1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((imbalance(&[3.0, 1.0]) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn makespan_adds_transfer_to_busiest() {
        assert!((makespan(&[2.0, 5.0], 1.0) - 6.0).abs() < 1e-12);
        assert_eq!(makespan(&[], 0.0), 0.0);
    }
}
