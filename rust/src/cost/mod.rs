//! Cost models driving the autotiling pass (§3.3).
//!
//! * [`cacheline`] — the paper's Fig.-4 model: "number of cache lines
//!   accessed, divided by the number of multiply-accumulate operations
//!   performed", with overflow accesses counted and a cap on tile
//!   memory. Computed analytically from the block's affine accesses
//!   (exactly, for rectilinear footprints), and cross-checkable against
//!   the trace-based count from the interpreter + `sim`.
//! * [`roofline`] — the Williams et al. roofline model referenced in
//!   §3.3: arithmetic intensity vs machine balance, used for the
//!   TPU-style targets where bandwidth, not lines, is the resource.
//! * [`search`] — tile-size search over a candidate space (exhaustive /
//!   powers-of-two / divisors), with the search-space heuristics the
//!   paper mentions.
//! * [`pipeline`] — the Fig.-4 model generalized from one flat block to
//!   a whole compiled program tree; ranks candidate pass pipelines for
//!   the coordinator's autotuner (`coordinator::tune`).
//! * [`transfer`] — the inter-shard link model for heterogeneous
//!   sharding (`hw::shard` / `exec::shard`): bytes crossing a shard
//!   boundary priced as latency + bytes/bandwidth, plus the
//!   makespan/imbalance folds the shard-assignment search minimizes.

pub mod cacheline;
pub mod pipeline;
pub mod roofline;
pub mod search;
pub mod transfer;

pub use cacheline::{tiling_cost, CostParams, TileCost};
pub use pipeline::{predicted_program_cost, ProgramCost};
pub use roofline::{MachineRoof, RooflineEstimate};
pub use search::{best_tiling, SearchSpace, SearchStats};
pub use transfer::{imbalance, makespan, LinkModel};
