//! Scalar types, tensor view types, and hardware locations.

use std::fmt;

/// Element data types. The engines compute in f32 registers regardless
/// (see `exec`), but dtypes drive the *storage* representation (the
/// buffer layer stores f32/f64/i32 natively and i8 through an affine
/// quantization — see `exec::buffer`), printing fidelity (the paper's
/// Fig. 5 uses `i8`), element sizes for the cache-line cost model, and
/// the stencil pass's dtype matching.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    I8,
    I16,
    I32,
    F16,
    BF16,
    F32,
    F64,
}

impl DType {
    pub fn size_bytes(self) -> u64 {
        match self {
            DType::I8 => 1,
            DType::I16 | DType::F16 | DType::BF16 => 2,
            DType::I32 | DType::F32 => 4,
            DType::F64 => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::I8 => "i8",
            DType::I16 => "i16",
            DType::I32 => "i32",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
            DType::F32 => "f32",
            DType::F64 => "f64",
        }
    }

    pub fn parse(s: &str) -> Option<DType> {
        Some(match s {
            "i8" => DType::I8,
            "i16" => DType::I16,
            "i32" => DType::I32,
            "f16" => DType::F16,
            "bf16" => DType::BF16,
            "f32" => DType::F32,
            "f64" => DType::F64,
            _ => return None,
        })
    }

    /// The dtypes the execution storage layer represents natively:
    /// f32, f64, i32, and quantized i8 (everything else stores at f32
    /// precision). These are the dtypes the CLI `--dtype` flag, the
    /// differential sweep, and the e2e bench iterate over.
    pub const STORAGE: [DType; 4] = [DType::F32, DType::F64, DType::I32, DType::I8];
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// One dimension of a tensor view: logical size and physical stride
/// (in elements). Fig. 5 prints these as `i8(12, 16, 8):(128, 8, 1)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Dim {
    pub size: u64,
    pub stride: i64,
}

/// A tensor view type: dtype + per-dimension size/stride.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorType {
    pub dtype: DType,
    pub dims: Vec<Dim>,
}

impl TensorType {
    /// Contiguous row-major layout for the given sizes.
    pub fn contiguous(dtype: DType, sizes: &[u64]) -> TensorType {
        let mut dims: Vec<Dim> = sizes.iter().map(|&s| Dim { size: s, stride: 0 }).collect();
        let mut stride = 1i64;
        for d in dims.iter_mut().rev() {
            d.stride = stride;
            stride *= d.size as i64;
        }
        TensorType { dtype, dims }
    }

    /// Same sizes/strides, different dtype.
    pub fn with_dtype(&self, dtype: DType) -> TensorType {
        TensorType { dtype, dims: self.dims.clone() }
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn sizes(&self) -> Vec<u64> {
        self.dims.iter().map(|d| d.size).collect()
    }

    pub fn strides(&self) -> Vec<i64> {
        self.dims.iter().map(|d| d.stride).collect()
    }

    /// Number of logical elements in the view.
    pub fn elems(&self) -> u64 {
        self.dims.iter().map(|d| d.size).product()
    }

    /// Number of bytes of the logical elements.
    pub fn logical_bytes(&self) -> u64 {
        self.elems() * self.dtype.size_bytes()
    }

    /// One-past-the-max flat element offset reachable from the view
    /// origin (assuming non-negative strides): the allocation extent
    /// needed to hold the view.
    pub fn span_elems(&self) -> u64 {
        1 + self
            .dims
            .iter()
            .map(|d| (d.size as i64 - 1).max(0) * d.stride.max(0))
            .sum::<i64>() as u64
    }

    /// Flat element offset for a multi-index (lengths must match).
    pub fn flat(&self, index: &[i64]) -> i64 {
        debug_assert_eq!(index.len(), self.dims.len());
        index.iter().zip(&self.dims).map(|(&i, d)| i * d.stride).sum()
    }

    /// True if the layout is the canonical contiguous row-major one.
    pub fn is_contiguous(&self) -> bool {
        *self == TensorType::contiguous(self.dtype, &self.sizes())
    }
}

impl fmt::Display for TensorType {
    /// Fig.-5 style: `i8(3, 4, 16):(256, 16, 1)`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}(", self.dtype)?;
        for (i, d) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{}", d.size)?;
        }
        write!(f, "):(")?;
        for (i, d) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{}", d.stride)?;
        }
        write!(f, ")")
    }
}

/// A hardware location for a buffer (§3.2 "Refinements may also include
/// the hardware location of the buffer"): memory unit name, optional
/// bank (an affine of iteration indexes, so banking can be
/// index-dependent), optional fixed address.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct Location {
    pub unit: String,
    pub bank: Option<crate::poly::Affine>,
    pub addr: Option<u64>,
}

impl Location {
    pub fn unit(name: &str) -> Location {
        Location { unit: name.to_string(), bank: None, addr: None }
    }

    pub fn banked(name: &str, bank: crate::poly::Affine) -> Location {
        Location { unit: name.to_string(), bank: Some(bank), addr: None }
    }
}

impl fmt::Display for Location {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "loc({}", self.unit)?;
        if let Some(b) = &self.bank {
            write!(f, ", bank={b}")?;
        }
        if let Some(a) = self.addr {
            write!(f, ", addr={a}")?;
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_strides_row_major() {
        let t = TensorType::contiguous(DType::I8, &[12, 16, 8]);
        assert_eq!(t.strides(), vec![128, 8, 1]);
        assert_eq!(t.elems(), 12 * 16 * 8);
        assert_eq!(t.span_elems(), 12 * 16 * 8);
        assert!(t.is_contiguous());
    }

    #[test]
    fn flat_offsets() {
        let t = TensorType::contiguous(DType::F32, &[3, 4]);
        assert_eq!(t.flat(&[0, 0]), 0);
        assert_eq!(t.flat(&[1, 2]), 6);
        assert_eq!(t.flat(&[2, 3]), 11);
    }

    #[test]
    fn strided_view_span() {
        // A (3,4) view cut out of a row of a (12,16) tensor: strides (16,1)
        let t = TensorType {
            dtype: DType::F32,
            dims: vec![Dim { size: 3, stride: 16 }, Dim { size: 4, stride: 1 }],
        };
        assert_eq!(t.elems(), 12);
        assert_eq!(t.span_elems(), 2 * 16 + 3 + 1);
        assert!(!t.is_contiguous());
    }

    #[test]
    fn display_fig5_format() {
        let t = TensorType::contiguous(DType::I8, &[3, 3, 16, 8]);
        assert_eq!(t.to_string(), "i8(3, 3, 16, 8):(384, 128, 8, 1)");
    }

    #[test]
    fn dtype_roundtrip() {
        for d in
            [DType::I8, DType::I16, DType::I32, DType::F16, DType::BF16, DType::F32, DType::F64]
        {
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::parse("i64"), None);
    }

    #[test]
    fn location_display() {
        use crate::poly::Affine;
        let l = Location::banked("SRAM", Affine::var("p"));
        assert_eq!(l.to_string(), "loc(SRAM, bank=p)");
    }
}
