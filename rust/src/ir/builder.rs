//! Construction helpers for canonical Stripe blocks.
//!
//! The frontend lowers every Tile contraction to the same canonical
//! *flat* form (§1.3: "Stripe code representing a single tensor
//! operation can be represented as an unnested polyhedron"): one block
//! whose iteration space covers the whole operation, with size-1 leaf
//! refinements and a short scalar statement list. Passes then rewrite
//! this form into nested blocks.

use crate::poly::Affine;

use super::block::{AggOp, Block, Idx, IntrOp, RefDir, Refinement, Statement};
use super::types::{Dim, TensorType};

/// An operand of a canonical block: buffer name + per-dimension access
/// polynomials + the parent view's type (for strides and dtype).
#[derive(Debug, Clone)]
pub struct Operand {
    pub name: String,
    pub access: Vec<Affine>,
    pub ttype: TensorType,
}

impl Operand {
    pub fn new(name: &str, access: Vec<Affine>, ttype: &TensorType) -> Operand {
        Operand { name: name.to_string(), access, ttype: ttype.clone() }
    }
}

/// Scalar view type: size-1 in every dimension, parent strides kept (the
/// Fig.-5 leaf form `i8(1, 1, 1):(128, 8, 1)`).
pub fn scalar_view(parent: &TensorType) -> TensorType {
    TensorType {
        dtype: parent.dtype,
        dims: parent.dims.iter().map(|d| Dim { size: 1, stride: d.stride }).collect(),
    }
}

/// Make the `in` refinement for an operand at leaf granularity.
fn in_ref(op: &Operand) -> Refinement {
    Refinement::new(RefDir::In, &op.name, op.access.clone(), scalar_view(&op.ttype))
}

/// Make the `out` refinement for an operand at leaf granularity.
fn out_ref(op: &Operand, agg: AggOp) -> Refinement {
    Refinement::new(RefDir::Out, &op.name, op.access.clone(), scalar_view(&op.ttype)).with_agg(agg)
}

/// Build a contraction block: `out[f(x)] agg= combine(in0[g0(x)], in1[g1(x)])`
/// over the iteration space given by `idxs` and `constraints`.
///
/// With one input, `combine` is ignored and the input value is stored
/// directly (e.g. a max-pool is `out max= in`).
pub fn contraction(
    name: &str,
    idxs: &[(&str, u64)],
    constraints: Vec<Affine>,
    out: Operand,
    agg: AggOp,
    inputs: &[Operand],
    combine: IntrOp,
) -> Block {
    assert!(!inputs.is_empty() && inputs.len() <= 2);
    let mut b = Block::new(name);
    b.idxs = idxs.iter().map(|(n, r)| Idx::range(n, *r)).collect();
    b.constraints = constraints;
    for i in inputs {
        b.refs.push(in_ref(i));
    }
    b.refs.push(out_ref(&out, agg));
    // Statement list.
    let mut scalars = Vec::new();
    for i in inputs {
        let s = format!("${}", i.name);
        b.stmts.push(Statement::Load { from: i.name.clone(), into: s.clone() });
        scalars.push(s);
    }
    let result = if inputs.len() == 2 {
        let out_scalar = format!("${}", out.name);
        b.stmts.push(Statement::Intrinsic {
            op: combine,
            inputs: scalars.clone(),
            output: out_scalar.clone(),
        });
        out_scalar
    } else {
        scalars[0].clone()
    };
    b.stmts.push(Statement::Store { from: result, into: out.name.clone() });
    b
}

/// Build an elementwise block applying a chain of unary intrinsics (in
/// order) to a single input: `out[x] = opN(...(op1(in[x])))`.
pub fn elementwise_unary(
    name: &str,
    idxs: &[(&str, u64)],
    out: Operand,
    input: Operand,
    ops: &[IntrOp],
) -> Block {
    let mut b = Block::new(name);
    b.idxs = idxs.iter().map(|(n, r)| Idx::range(n, *r)).collect();
    b.refs.push(in_ref(&input));
    b.refs.push(out_ref(&out, AggOp::Assign));
    let mut cur = format!("${}", input.name);
    b.stmts.push(Statement::Load { from: input.name.clone(), into: cur.clone() });
    for (i, op) in ops.iter().enumerate() {
        assert_eq!(op.arity(), 1, "elementwise_unary takes unary ops");
        let next = format!("$t{i}");
        b.stmts.push(Statement::Intrinsic {
            op: *op,
            inputs: vec![cur.clone()],
            output: next.clone(),
        });
        cur = next;
    }
    b.stmts.push(Statement::Store { from: cur, into: out.name.clone() });
    b
}

/// Build an elementwise binary block: `out[x] = op(a[x], b[x])`.
pub fn elementwise_binary(
    name: &str,
    idxs: &[(&str, u64)],
    out: Operand,
    a: Operand,
    bb: Operand,
    op: IntrOp,
) -> Block {
    contraction(name, idxs, Vec::new(), out, AggOp::Assign, &[a, bb], op)
}

/// Identity-style access: one index per dimension, `[x, y, ...]`.
pub fn identity_access(names: &[&str]) -> Vec<Affine> {
    names.iter().map(|n| Affine::var(n)).collect()
}

/// The boundary ("halo") constraints for an access `a(x)` that must stay
/// within `[0, size)`: returns `a >= 0` and `size - 1 - a >= 0`.
pub fn containment_constraints(access: &Affine, size: u64) -> [Affine; 2] {
    let lower = access.clone();
    let mut upper = access.scale(-1);
    upper.offset += size as i64 - 1;
    [lower, upper]
}

/// Fig.-4/5 running example: the 3×3 same-padded convolution
/// `O[x,y,k] += I[x+i-1, y+j-1, c] * F[i,j,k,c]` with I: (12,16,8) i8,
/// O: (12,16,16) i8, F: (3,3,16,8) i8.
pub fn fig5_conv_block() -> Block {
    use super::types::DType;
    let i_t = TensorType::contiguous(DType::I8, &[12, 16, 8]);
    let f_t = TensorType::contiguous(DType::I8, &[3, 3, 16, 8]);
    let o_t = TensorType::contiguous(DType::I8, &[12, 16, 16]);
    let ax = Affine::from_terms(&[("x", 1), ("i", 1)], -1);
    let ay = Affine::from_terms(&[("y", 1), ("j", 1)], -1);
    let mut cons = Vec::new();
    cons.extend(containment_constraints(&ax, 12));
    cons.extend(containment_constraints(&ay, 16));
    contraction(
        "conv",
        &[("x", 12), ("y", 16), ("i", 3), ("j", 3), ("c", 8), ("k", 16)],
        cons,
        Operand::new("O", vec![Affine::var("x"), Affine::var("y"), Affine::var("k")], &o_t),
        AggOp::Add,
        &[
            Operand::new("I", vec![ax, ay, Affine::var("c")], &i_t),
            Operand::new(
                "F",
                vec![Affine::var("i"), Affine::var("j"), Affine::var("k"), Affine::var("c")],
                &f_t,
            ),
        ],
        IntrOp::Mul,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::types::DType;

    #[test]
    fn fig5_conv_shape() {
        let b = fig5_conv_block();
        assert_eq!(b.idxs.len(), 6);
        assert_eq!(b.constraints.len(), 4);
        assert_eq!(b.refs.len(), 3);
        assert_eq!(b.stmts.len(), 4); // load, load, mul, store
        // Valid iterations: x+i-1 in [0,12), y+j-1 in [0,16)
        let expected = (0..12i64)
            .flat_map(|x| (0..3i64).map(move |i| (x, i)))
            .filter(|(x, i)| (0..12).contains(&(x + i - 1)))
            .count() as u64
            * (0..16i64)
                .flat_map(|y| (0..3i64).map(move |j| (y, j)))
                .filter(|(y, j)| (0..16).contains(&(y + j - 1)))
                .count() as u64
            * 8
            * 16;
        assert_eq!(b.iterations(), expected);
    }

    #[test]
    fn scalar_view_keeps_strides() {
        let t = TensorType::contiguous(DType::I8, &[12, 16, 8]);
        let s = scalar_view(&t);
        assert_eq!(s.sizes(), vec![1, 1, 1]);
        assert_eq!(s.strides(), vec![128, 8, 1]);
    }

    #[test]
    fn containment_bounds() {
        let a = Affine::from_terms(&[("x", 1), ("i", 1)], -1);
        let [lo, hi] = containment_constraints(&a, 12);
        // at x=0,i=0: a=-1 violates lo
        let names = vec!["x".to_string(), "i".to_string()];
        assert!(lo.eval_slices(&names, &[0, 0]) < 0);
        assert!(lo.eval_slices(&names, &[0, 1]) >= 0);
        // at x=11,i=2: a=12 violates hi (12 <= 11 required)
        assert!(hi.eval_slices(&names, &[11, 2]) < 0);
        assert!(hi.eval_slices(&names, &[11, 1]) >= 0);
    }

    #[test]
    fn unary_chain() {
        let t = TensorType::contiguous(DType::F32, &[8]);
        let b = elementwise_unary(
            "relu",
            &[("x", 8)],
            Operand::new("O", identity_access(&["x"]), &t),
            Operand::new("I", identity_access(&["x"]), &t),
            &[IntrOp::Relu],
        );
        assert_eq!(b.stmts.len(), 3);
        assert_eq!(b.refs.len(), 2);
    }
}
