//! Blocks, refinements, and statements — the core Stripe structures.

use std::collections::{BTreeMap, BTreeSet};

use crate::poly::{Affine, Polyhedron};

use super::types::{Location, TensorType};

/// Aggregation operations (Definition 2's associative & commutative
/// `a_B`). `Assign` is the paper's special aggregation that makes writes
/// from multiple iterations illegal.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AggOp {
    Assign,
    Add,
    Mul,
    Max,
    Min,
}

impl AggOp {
    pub fn name(self) -> &'static str {
        match self {
            AggOp::Assign => "assign",
            AggOp::Add => "add",
            AggOp::Mul => "mul",
            AggOp::Max => "max",
            AggOp::Min => "min",
        }
    }

    pub fn parse(s: &str) -> Option<AggOp> {
        Some(match s {
            "assign" => AggOp::Assign,
            "add" => AggOp::Add,
            "mul" => AggOp::Mul,
            "max" => AggOp::Max,
            "min" => AggOp::Min,
            _ => return None,
        })
    }

    /// Combine two written values per Definition 2.
    pub fn combine(self, a: f32, b: f32) -> f32 {
        match self {
            AggOp::Assign => b,
            AggOp::Add => a + b,
            AggOp::Mul => a * b,
            AggOp::Max => a.max(b),
            AggOp::Min => a.min(b),
        }
    }
}

/// Direction of a refinement: how the child block uses the buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RefDir {
    In,
    Out,
    InOut,
    /// A block-local allocation (scratch / localized intermediate); has
    /// no parent buffer.
    Temp,
}

impl RefDir {
    pub fn name(self) -> &'static str {
        match self {
            RefDir::In => "in",
            RefDir::Out => "out",
            RefDir::InOut => "inout",
            RefDir::Temp => "tmp",
        }
    }

    pub fn is_read(self) -> bool {
        matches!(self, RefDir::In | RefDir::InOut)
    }

    pub fn is_write(self) -> bool {
        matches!(self, RefDir::Out | RefDir::InOut)
    }
}

/// A refinement: brings a sub-view of a parent buffer into scope in a
/// child block (§3.2). `access` gives the per-dimension offset of the
/// child view's origin within the parent view, as affine polynomials of
/// the *enclosing block's* indexes; `ttype` gives the child view's
/// size/stride layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Refinement {
    pub dir: RefDir,
    /// Name of the buffer in the parent scope (`""` for `Temp`).
    pub from: String,
    /// Local name in this block's scope (commonly equal to `from`).
    pub into: String,
    /// Per-parent-dimension affine offsets of the view origin.
    pub access: Vec<Affine>,
    /// Child view layout.
    pub ttype: TensorType,
    /// Aggregation for writes through this refinement.
    pub agg: AggOp,
    /// Optional hardware placement.
    pub location: Option<Location>,
}

impl Refinement {
    pub fn new(dir: RefDir, name: &str, access: Vec<Affine>, ttype: TensorType) -> Refinement {
        Refinement {
            dir,
            from: name.to_string(),
            into: name.to_string(),
            access,
            ttype,
            agg: AggOp::Assign,
            location: None,
        }
    }

    pub fn with_agg(mut self, agg: AggOp) -> Refinement {
        self.agg = agg;
        self
    }

    pub fn with_into(mut self, into: &str) -> Refinement {
        self.into = into.to_string();
        self
    }

    pub fn with_location(mut self, loc: Location) -> Refinement {
        self.location = Some(loc);
        self
    }

    /// Zero-offset access of the given rank.
    pub fn zero_access(rank: usize) -> Vec<Affine> {
        vec![Affine::zero(); rank]
    }
}

/// One iteration index of a block. A *passed* index (`affine` set) has
/// range 1 and takes its value from an affine of the parent block's
/// indexes — the paper's "any parent index used [must] be explicitly
/// passed to the child block".
#[derive(Debug, Clone, PartialEq)]
pub struct Idx {
    pub name: String,
    pub range: u64,
    pub affine: Option<Affine>,
}

impl Idx {
    pub fn range(name: &str, range: u64) -> Idx {
        Idx { name: name.to_string(), range, affine: None }
    }

    pub fn passed(name: &str, value: Affine) -> Idx {
        Idx { name: name.to_string(), range: 1, affine: Some(value) }
    }
}

/// Scalar intrinsics (§3.2: "An intrinsic works with scalar values").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IntrOp {
    Add,
    Sub,
    Mul,
    Div,
    Neg,
    Max,
    Min,
    Exp,
    Log,
    Sqrt,
    Tanh,
    /// max(x, 0) — common enough in ML lowering to warrant an intrinsic.
    Relu,
    /// select(c, a, b): c != 0 ? a : b
    Select,
    /// a < b ? 1 : 0
    Lt,
}

impl IntrOp {
    pub fn name(self) -> &'static str {
        match self {
            IntrOp::Add => "add",
            IntrOp::Sub => "sub",
            IntrOp::Mul => "mul",
            IntrOp::Div => "div",
            IntrOp::Neg => "neg",
            IntrOp::Max => "max",
            IntrOp::Min => "min",
            IntrOp::Exp => "exp",
            IntrOp::Log => "log",
            IntrOp::Sqrt => "sqrt",
            IntrOp::Tanh => "tanh",
            IntrOp::Relu => "relu",
            IntrOp::Select => "select",
            IntrOp::Lt => "lt",
        }
    }

    pub fn parse(s: &str) -> Option<IntrOp> {
        Some(match s {
            "add" => IntrOp::Add,
            "sub" => IntrOp::Sub,
            "mul" => IntrOp::Mul,
            "div" => IntrOp::Div,
            "neg" => IntrOp::Neg,
            "max" => IntrOp::Max,
            "min" => IntrOp::Min,
            "exp" => IntrOp::Exp,
            "log" => IntrOp::Log,
            "sqrt" => IntrOp::Sqrt,
            "tanh" => IntrOp::Tanh,
            "relu" => IntrOp::Relu,
            "select" => IntrOp::Select,
            "lt" => IntrOp::Lt,
            _ => return None,
        })
    }

    pub fn arity(self) -> usize {
        match self {
            IntrOp::Neg
            | IntrOp::Exp
            | IntrOp::Log
            | IntrOp::Sqrt
            | IntrOp::Tanh
            | IntrOp::Relu => 1,
            IntrOp::Select => 3,
            _ => 2,
        }
    }

    pub fn eval(self, args: &[f32]) -> f32 {
        match self {
            IntrOp::Add => args[0] + args[1],
            IntrOp::Sub => args[0] - args[1],
            IntrOp::Mul => args[0] * args[1],
            IntrOp::Div => args[0] / args[1],
            IntrOp::Neg => -args[0],
            IntrOp::Max => args[0].max(args[1]),
            IntrOp::Min => args[0].min(args[1]),
            IntrOp::Exp => args[0].exp(),
            IntrOp::Log => args[0].ln(),
            IntrOp::Sqrt => args[0].sqrt(),
            IntrOp::Tanh => args[0].tanh(),
            IntrOp::Relu => args[0].max(0.0),
            IntrOp::Select => {
                if args[0] != 0.0 {
                    args[1]
                } else {
                    args[2]
                }
            }
            IntrOp::Lt => {
                if args[0] < args[1] {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// A *special* function: a complex tensor-granularity operation that is
/// "inappropriate to represent as blocks of operations on scalars"
/// (§3.2), e.g. scatter/gather/reshape. Operands name refinements in
/// scope; `attrs` carry op-specific parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Special {
    pub name: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub attrs: BTreeMap<String, String>,
}

/// A statement in a block's (single, semantically serial) statement list.
#[derive(Debug, Clone, PartialEq)]
pub enum Statement {
    /// Nested parallel polyhedral block.
    Block(Box<Block>),
    /// `$into = load(from)` — read the scalar at a refinement's origin.
    Load { from: String, into: String },
    /// `into = store($from)` — write a scalar through a refinement,
    /// combining with the refinement's aggregation op.
    Store { from: String, into: String },
    /// `$out = op($in...)` — scalar computation.
    Intrinsic { op: IntrOp, inputs: Vec<String>, output: String },
    /// `$out = <constant>`.
    Constant { output: String, value: f64 },
    /// Tensor-granularity special function.
    Special(Special),
}

impl Statement {
    pub fn as_block(&self) -> Option<&Block> {
        match self {
            Statement::Block(b) => Some(b),
            _ => None,
        }
    }

    pub fn as_block_mut(&mut self) -> Option<&mut Block> {
        match self {
            Statement::Block(b) => Some(b),
            _ => None,
        }
    }
}

/// A Stripe block: one parallel polyhedral block of the Nested
/// Polyhedral Model.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Block {
    /// Diagnostic name (`conv1`, `conv1_tile`, ...); not semantic.
    pub name: String,
    /// Iteration indexes (range and passed).
    pub idxs: Vec<Idx>,
    /// Additional (non-rectilinear) constraints: each `c(x) >= 0`, over
    /// this block's index names.
    pub constraints: Vec<Affine>,
    /// Buffer views in scope in this block.
    pub refs: Vec<Refinement>,
    /// The single statement list (identical for every iteration).
    pub stmts: Vec<Statement>,
    /// Free-form, non-semantic tags for passes and the HAL.
    pub tags: BTreeSet<String>,
    /// Optional execution placement of the whole block.
    pub location: Option<Location>,
}

impl Block {
    pub fn new(name: &str) -> Block {
        Block { name: name.to_string(), ..Default::default() }
    }

    /// The iteration-space polyhedron (ranged indexes only; passed
    /// indexes are range-1 and contribute nothing to the space).
    pub fn iteration_space(&self) -> Polyhedron {
        Polyhedron {
            dims: self
                .idxs
                .iter()
                .map(|i| crate::poly::polyhedron::Dim { name: i.name.clone(), range: i.range })
                .collect(),
            constraints: self.constraints.clone(),
        }
    }

    /// Names of all indexes (ranged + passed).
    pub fn idx_names(&self) -> Vec<String> {
        self.idxs.iter().map(|i| i.name.clone()).collect()
    }

    pub fn idx(&self, name: &str) -> Option<&Idx> {
        self.idxs.iter().find(|i| i.name == name)
    }

    pub fn find_ref(&self, into: &str) -> Option<&Refinement> {
        self.refs.iter().find(|r| r.into == into)
    }

    pub fn find_ref_mut(&mut self, into: &str) -> Option<&mut Refinement> {
        self.refs.iter_mut().find(|r| r.into == into)
    }

    pub fn has_tag(&self, tag: &str) -> bool {
        self.tags.contains(tag)
    }

    pub fn add_tag(&mut self, tag: &str) {
        self.tags.insert(tag.to_string());
    }

    /// Number of iterations (lattice points satisfying constraints).
    pub fn iterations(&self) -> u64 {
        self.iteration_space().count_points()
    }

    /// Total iterations of this block times all nested blocks — a rough
    /// "work" measure used by cost heuristics.
    pub fn total_leaf_iterations(&self) -> u64 {
        let own = self.iterations();
        let inner: u64 = self
            .stmts
            .iter()
            .map(|s| match s {
                Statement::Block(b) => b.total_leaf_iterations(),
                _ => 0,
            })
            .sum::<u64>()
            .max(1);
        own * inner
    }

    /// Immutable iterator over directly nested blocks.
    pub fn child_blocks(&self) -> impl Iterator<Item = &Block> {
        self.stmts.iter().filter_map(|s| s.as_block())
    }

    /// Mutable iterator over directly nested blocks.
    pub fn child_blocks_mut(&mut self) -> impl Iterator<Item = &mut Block> {
        self.stmts.iter_mut().filter_map(|s| s.as_block_mut())
    }

    /// Depth of block nesting (a leaf compute block has depth 1).
    pub fn depth(&self) -> usize {
        1 + self.child_blocks().map(|b| b.depth()).max().unwrap_or(0)
    }

    /// Walk all blocks in the tree (preorder), calling `f` on each.
    pub fn walk<'a>(&'a self, f: &mut impl FnMut(&'a Block)) {
        f(self);
        for b in self.child_blocks() {
            b.walk(f);
        }
    }

    /// Walk all blocks mutably (preorder).
    pub fn walk_mut(&mut self, f: &mut impl FnMut(&mut Block)) {
        f(self);
        for b in self.child_blocks_mut() {
            b.walk_mut(f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::types::DType;

    fn leaf() -> Block {
        let mut b = Block::new("leaf");
        b.idxs.push(Idx::range("x", 4));
        b.refs.push(Refinement::new(
            RefDir::Out,
            "O",
            vec![Affine::var("x")],
            TensorType::contiguous(DType::F32, &[1]),
        ));
        b.stmts.push(Statement::Constant { output: "$c".into(), value: 1.0 });
        b.stmts.push(Statement::Store { from: "$c".into(), into: "O".into() });
        b
    }

    #[test]
    fn iteration_space_from_idxs() {
        let b = leaf();
        assert_eq!(b.iterations(), 4);
        assert_eq!(b.iteration_space().rank(), 1);
    }

    #[test]
    fn passed_idx_has_range_one() {
        let i = Idx::passed("x", Affine::var("xp"));
        assert_eq!(i.range, 1);
        assert!(i.affine.is_some());
    }

    #[test]
    fn nesting_depth_and_walk() {
        let mut outer = Block::new("outer");
        outer.idxs.push(Idx::range("t", 3));
        outer.stmts.push(Statement::Block(Box::new(leaf())));
        assert_eq!(outer.depth(), 2);
        assert_eq!(outer.total_leaf_iterations(), 12);
        let mut names = Vec::new();
        outer.walk(&mut |b| names.push(b.name.clone()));
        assert_eq!(names, vec!["outer", "leaf"]);
    }

    #[test]
    fn agg_combine() {
        assert_eq!(AggOp::Add.combine(2.0, 3.0), 5.0);
        assert_eq!(AggOp::Max.combine(2.0, 3.0), 3.0);
        assert_eq!(AggOp::Min.combine(2.0, 3.0), 2.0);
        assert_eq!(AggOp::Mul.combine(2.0, 3.0), 6.0);
        assert_eq!(AggOp::Assign.combine(2.0, 3.0), 3.0);
    }

    #[test]
    fn intrinsic_eval() {
        assert_eq!(IntrOp::Relu.eval(&[-1.0]), 0.0);
        assert_eq!(IntrOp::Relu.eval(&[2.0]), 2.0);
        assert_eq!(IntrOp::Select.eval(&[1.0, 5.0, 7.0]), 5.0);
        assert_eq!(IntrOp::Select.eval(&[0.0, 5.0, 7.0]), 7.0);
        assert_eq!(IntrOp::Lt.eval(&[1.0, 2.0]), 1.0);
        assert!((IntrOp::Exp.eval(&[0.0]) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn intrinsic_name_roundtrip() {
        for op in [
            IntrOp::Add,
            IntrOp::Sub,
            IntrOp::Mul,
            IntrOp::Div,
            IntrOp::Neg,
            IntrOp::Max,
            IntrOp::Min,
            IntrOp::Exp,
            IntrOp::Log,
            IntrOp::Sqrt,
            IntrOp::Tanh,
            IntrOp::Relu,
            IntrOp::Select,
            IntrOp::Lt,
        ] {
            assert_eq!(IntrOp::parse(op.name()), Some(op));
        }
    }

    #[test]
    fn refinement_builders() {
        let r = Refinement::new(
            RefDir::In,
            "I",
            Refinement::zero_access(3),
            TensorType::contiguous(DType::I8, &[12, 16, 8]),
        )
        .with_agg(AggOp::Add)
        .with_into("I_tile");
        assert_eq!(r.agg, AggOp::Add);
        assert_eq!(r.into, "I_tile");
        assert_eq!(r.from, "I");
        assert!(r.dir.is_read() && !r.dir.is_write());
    }
}
