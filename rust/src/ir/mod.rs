//! The Stripe intermediate representation (§3.2 of the paper).
//!
//! Stripe represents *parallel polyhedral blocks* (Definition 2) with the
//! [`Block`] structure:
//!
//! * a polyhedral iteration space — named indexes with ranges plus affine
//!   constraints (`c(x) ≥ 0`);
//! * a **single** statement list shared by every iteration (what varies
//!   per iteration is only which buffer elements are accessed);
//! * explicitly declared I/O buffers brought into scope through
//!   [`Refinement`]s — sub-views with per-dimension affine offset
//!   (`access`), size/stride layout, an aggregation operation, and an
//!   optional hardware [`Location`];
//! * statements that are nested blocks, scalar *intrinsics*
//!   (load/store/arithmetic), or *special* functions (tensor-granularity
//!   ops like scatter/gather);
//! * *tags* — free-form strings with no semantics, consumed by passes
//!   and the hardware abstraction layer.
//!
//! Sub-modules:
//! * [`types`] — dtypes, tensor shapes (size+stride per dim), locations;
//! * [`block`] — blocks, refinements, statements, aggregations;
//! * [`program`] — a whole network: named top-level buffers + root block;
//! * [`builder`] — ergonomic construction helpers used by the frontend
//!   and by tests;
//! * [`printer`] / [`parser`] — the Fig.-5-style textual format
//!   (round-trips: `parse(print(p)) == p`);
//! * [`validate`] — checks the Definition-2 conditions and the scoping
//!   rules (explicit index passing, refinement containment).

pub mod block;
pub mod builder;
pub mod parser;
pub mod printer;
pub mod program;
pub mod types;
pub mod validate;

pub use block::{AggOp, Block, Idx, IntrOp, RefDir, Refinement, Special, Statement};
pub use program::{BufKind, Buffer, Program};
pub use types::{DType, Dim, Location, TensorType};
