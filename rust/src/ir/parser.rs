//! Parser for the textual Stripe format produced by [`super::printer`].
//!
//! Hand-written tokenizer + recursive descent. The parser is used by
//! golden tests (Fig. 5 before/after), by the CLI (`stripe run
//! file.stripe`), and round-trip property tests.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::poly::Affine;

use super::block::{AggOp, Block, Idx, IntrOp, RefDir, Refinement, Special, Statement};
use super::program::{BufKind, Buffer, Program};
use super::types::{DType, Dim, Location, TensorType};

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Scalar(String), // $name
    Int(i64),
    Float(f64),
    Punct(char),
    Arrow, // ->
    Ge,    // >=
}

fn tokenize(src: &str) -> Result<Vec<Tok>> {
    let mut out = Vec::new();
    let bytes: Vec<char> = src.chars().collect();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i];
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < bytes.len() && bytes[i + 1] == '/' {
            while i < bytes.len() && bytes[i] != '\n' {
                i += 1;
            }
            continue;
        }
        if c == '-' && i + 1 < bytes.len() && bytes[i + 1] == '>' {
            out.push(Tok::Arrow);
            i += 2;
            continue;
        }
        if c == '>' && i + 1 < bytes.len() && bytes[i + 1] == '=' {
            out.push(Tok::Ge);
            i += 2;
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            let mut is_float = false;
            while i < bytes.len() && (bytes[i].is_ascii_digit() || bytes[i] == '.') {
                if bytes[i] == '.' {
                    is_float = true;
                }
                i += 1;
            }
            // Exponent part
            if i < bytes.len() && (bytes[i] == 'e' || bytes[i] == 'E') {
                is_float = true;
                i += 1;
                if i < bytes.len() && (bytes[i] == '+' || bytes[i] == '-') {
                    i += 1;
                }
                while i < bytes.len() && bytes[i].is_ascii_digit() {
                    i += 1;
                }
            }
            let text: String = bytes[start..i].iter().collect();
            if is_float {
                out.push(Tok::Float(text.parse()?));
            } else {
                out.push(Tok::Int(text.parse()?));
            }
            continue;
        }
        if c == '$' {
            let start = i;
            i += 1;
            while i < bytes.len() && (bytes[i].is_alphanumeric() || bytes[i] == '_') {
                i += 1;
            }
            out.push(Tok::Scalar(bytes[start..i].iter().collect()));
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < bytes.len() && (bytes[i].is_alphanumeric() || bytes[i] == '_') {
                i += 1;
            }
            out.push(Tok::Ident(bytes[start..i].iter().collect()));
            continue;
        }
        if "[](){}:,=#*+-<>@".contains(c) {
            out.push(Tok::Punct(c));
            i += 1;
            continue;
        }
        bail!("unexpected character {c:?} at offset {i}");
    }
    Ok(out)
}

struct Parser {
    toks: Vec<Tok>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Result<Tok> {
        let t = self.toks.get(self.pos).cloned().ok_or_else(|| anyhow!("unexpected EOF"))?;
        self.pos += 1;
        Ok(t)
    }

    fn expect_punct(&mut self, c: char) -> Result<()> {
        match self.next()? {
            Tok::Punct(p) if p == c => Ok(()),
            t => bail!("expected {c:?}, got {t:?} at tok {}", self.pos - 1),
        }
    }

    fn eat_punct(&mut self, c: char) -> bool {
        if matches!(self.peek(), Some(Tok::Punct(p)) if *p == c) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect_ident(&mut self) -> Result<String> {
        match self.next()? {
            Tok::Ident(s) => Ok(s),
            t => bail!("expected identifier, got {t:?} at tok {}", self.pos - 1),
        }
    }

    fn expect_keyword(&mut self, kw: &str) -> Result<()> {
        let s = self.expect_ident()?;
        if s != kw {
            bail!("expected keyword {kw:?}, got {s:?}");
        }
        Ok(())
    }

    fn expect_int(&mut self) -> Result<i64> {
        match self.next()? {
            Tok::Int(n) => Ok(n),
            t => bail!("expected integer, got {t:?}"),
        }
    }

    // affine ::= term (("+"|"-") term)*
    // term   ::= INT | INT "*" IDENT | IDENT
    fn parse_affine(&mut self) -> Result<Affine> {
        let mut acc = Affine::zero();
        let mut sign = 1i64;
        // leading sign
        if self.eat_punct('-') {
            sign = -1;
        } else {
            let _ = self.eat_punct('+');
        }
        loop {
            match self.next()? {
                Tok::Int(n) => {
                    if self.eat_punct('*') {
                        let v = self.expect_ident()?;
                        acc.add_term(&v, sign * n);
                    } else {
                        acc.offset += sign * n;
                    }
                }
                Tok::Ident(v) => {
                    acc.add_term(&v, sign);
                }
                t => bail!("expected affine term, got {t:?}"),
            }
            if self.eat_punct('+') {
                sign = 1;
            } else if self.eat_punct('-') {
                sign = -1;
            } else {
                break;
            }
        }
        Ok(acc)
    }

    // type ::= dtype "(" INT,* ")" ":" "(" INT,* ")"
    fn parse_type(&mut self) -> Result<TensorType> {
        let d = self.expect_ident()?;
        let dtype = DType::parse(&d).ok_or_else(|| anyhow!("unknown dtype {d:?}"))?;
        self.expect_punct('(')?;
        let mut sizes = Vec::new();
        if !self.eat_punct(')') {
            loop {
                sizes.push(self.expect_int()? as u64);
                if !self.eat_punct(',') {
                    break;
                }
            }
            self.expect_punct(')')?;
        }
        self.expect_punct(':')?;
        self.expect_punct('(')?;
        let mut strides = Vec::new();
        if !self.eat_punct(')') {
            loop {
                let neg = self.eat_punct('-');
                let n = self.expect_int()?;
                strides.push(if neg { -n } else { n });
                if !self.eat_punct(',') {
                    break;
                }
            }
            self.expect_punct(')')?;
        }
        if sizes.len() != strides.len() {
            bail!("size/stride rank mismatch");
        }
        Ok(TensorType {
            dtype,
            dims: sizes
                .into_iter()
                .zip(strides)
                .map(|(size, stride)| Dim { size, stride })
                .collect(),
        })
    }

    // loc ::= "loc" "(" IDENT ("," "bank" "=" affine)? ("," "addr" "=" INT)? ")"
    fn parse_location(&mut self) -> Result<Location> {
        self.expect_keyword("loc")?;
        self.expect_punct('(')?;
        let unit = self.expect_ident()?;
        let mut loc = Location::unit(&unit);
        while self.eat_punct(',') {
            let key = self.expect_ident()?;
            self.expect_punct('=')?;
            match key.as_str() {
                "bank" => loc.bank = Some(self.parse_affine()?),
                "addr" => loc.addr = Some(self.expect_int()? as u64),
                k => bail!("unknown location key {k:?}"),
            }
        }
        self.expect_punct(')')?;
        Ok(loc)
    }

    fn at_location(&self) -> bool {
        matches!(self.peek(), Some(Tok::Ident(s)) if s == "loc")
    }

    // block ::= "block" NAME tag* loc? "[" idx,* "]" "(" decl* ")" "{" stmt* "}"
    fn parse_block(&mut self) -> Result<Block> {
        self.expect_keyword("block")?;
        let name = self.expect_ident()?;
        let mut b = Block::new(&name);
        while self.eat_punct('#') {
            b.tags.insert(self.expect_ident()?);
        }
        if self.at_location() {
            b.location = Some(self.parse_location()?);
        }
        self.expect_punct('[')?;
        if !self.eat_punct(']') {
            loop {
                let n = self.expect_ident()?;
                if self.eat_punct(':') {
                    let r = self.expect_int()?;
                    b.idxs.push(Idx::range(&n, r as u64));
                } else {
                    self.expect_punct('=')?;
                    b.idxs.push(Idx::passed(&n, self.parse_affine()?));
                }
                if !self.eat_punct(',') {
                    break;
                }
            }
            self.expect_punct(']')?;
        }
        self.expect_punct('(')?;
        // Declarations: refinements start with a direction keyword,
        // constraints with anything affine.
        loop {
            match self.peek() {
                Some(Tok::Punct(')')) => {
                    self.pos += 1;
                    break;
                }
                Some(Tok::Ident(s)) if RefDir_parse(s).is_some() => {
                    let r = self.parse_refinement()?;
                    b.refs.push(r);
                }
                Some(_) => {
                    let a = self.parse_affine()?;
                    match self.next()? {
                        Tok::Ge => {}
                        t => bail!("expected >= in constraint, got {t:?}"),
                    }
                    let z = self.expect_int()?;
                    if z != 0 {
                        bail!("constraints must compare against 0");
                    }
                    b.constraints.push(a);
                }
                None => bail!("unexpected EOF in block declarations"),
            }
        }
        self.expect_punct('{')?;
        loop {
            if self.eat_punct('}') {
                break;
            }
            b.stmts.push(self.parse_stmt()?);
        }
        Ok(b)
    }

    fn parse_refinement(&mut self) -> Result<Refinement> {
        let dirw = self.expect_ident()?;
        let dir = RefDir_parse(&dirw).unwrap();
        let from = self.expect_ident()?;
        let mut into = from.clone();
        if matches!(self.peek(), Some(Tok::Ident(s)) if s == "as") {
            self.pos += 1;
            into = self.expect_ident()?;
        }
        self.expect_punct('[')?;
        let mut access = Vec::new();
        if !self.eat_punct(']') {
            loop {
                access.push(self.parse_affine()?);
                if !self.eat_punct(',') {
                    break;
                }
            }
            self.expect_punct(']')?;
        }
        let mut agg = AggOp::Assign;
        if self.eat_punct(':') {
            let a = self.expect_ident()?;
            agg = AggOp::parse(&a).ok_or_else(|| anyhow!("unknown aggregation {a:?}"))?;
        }
        let ttype = self.parse_type()?;
        let mut r = Refinement {
            dir,
            from: if dir == RefDir::Temp { String::new() } else { from },
            into,
            access,
            ttype,
            agg,
            location: None,
        };
        if self.at_location() {
            r.location = Some(self.parse_location()?);
        }
        Ok(r)
    }

    fn parse_stmt(&mut self) -> Result<Statement> {
        match self.peek() {
            Some(Tok::Ident(s)) if s == "block" => {
                Ok(Statement::Block(Box::new(self.parse_block()?)))
            }
            Some(Tok::Ident(s)) if s == "special" => {
                self.pos += 1;
                let name = self.expect_ident()?;
                self.expect_punct('(')?;
                let mut inputs = Vec::new();
                if !self.eat_punct(')') {
                    loop {
                        inputs.push(self.expect_ident()?);
                        if !self.eat_punct(',') {
                            break;
                        }
                    }
                    self.expect_punct(')')?;
                }
                match self.next()? {
                    Tok::Arrow => {}
                    t => bail!("expected -> in special, got {t:?}"),
                }
                self.expect_punct('(')?;
                let mut outputs = Vec::new();
                if !self.eat_punct(')') {
                    loop {
                        outputs.push(self.expect_ident()?);
                        if !self.eat_punct(',') {
                            break;
                        }
                    }
                    self.expect_punct(')')?;
                }
                let mut attrs = BTreeMap::new();
                if self.eat_punct('[') {
                    loop {
                        let k = self.expect_ident()?;
                        self.expect_punct('=')?;
                        let v = match self.next()? {
                            Tok::Ident(s) => s,
                            Tok::Int(n) => n.to_string(),
                            Tok::Float(f) => f.to_string(),
                            t => bail!("bad attr value {t:?}"),
                        };
                        attrs.insert(k, v);
                        if !self.eat_punct(',') {
                            break;
                        }
                    }
                    self.expect_punct(']')?;
                }
                Ok(Statement::Special(Special { name, inputs, outputs, attrs }))
            }
            Some(Tok::Scalar(_)) => {
                let out = match self.next()? {
                    Tok::Scalar(s) => s,
                    _ => unreachable!(),
                };
                self.expect_punct('=')?;
                match self.next()? {
                    Tok::Ident(w) if w == "load" => {
                        self.expect_punct('(')?;
                        let from = self.expect_ident()?;
                        self.expect_punct(')')?;
                        Ok(Statement::Load { from, into: out })
                    }
                    Tok::Ident(w) => {
                        let op = IntrOp::parse(&w)
                            .ok_or_else(|| anyhow!("unknown intrinsic {w:?}"))?;
                        self.expect_punct('(')?;
                        let mut inputs = Vec::new();
                        if !self.eat_punct(')') {
                            loop {
                                match self.next()? {
                                    Tok::Scalar(s) => inputs.push(s),
                                    t => bail!("intrinsic args must be scalars, got {t:?}"),
                                }
                                if !self.eat_punct(',') {
                                    break;
                                }
                            }
                            self.expect_punct(')')?;
                        }
                        Ok(Statement::Intrinsic { op, inputs, output: out })
                    }
                    Tok::Float(v) => Ok(Statement::Constant { output: out, value: v }),
                    Tok::Int(v) => Ok(Statement::Constant { output: out, value: v as f64 }),
                    Tok::Punct('-') => match self.next()? {
                        Tok::Float(v) => Ok(Statement::Constant { output: out, value: -v }),
                        Tok::Int(v) => {
                            Ok(Statement::Constant { output: out, value: -(v as f64) })
                        }
                        t => bail!("expected number after '-', got {t:?}"),
                    },
                    t => bail!("bad statement rhs {t:?}"),
                }
            }
            Some(Tok::Ident(_)) => {
                // buffer = store($scalar)
                let into = self.expect_ident()?;
                self.expect_punct('=')?;
                self.expect_keyword("store")?;
                self.expect_punct('(')?;
                let from = match self.next()? {
                    Tok::Scalar(s) => s,
                    t => bail!("store arg must be a scalar, got {t:?}"),
                };
                self.expect_punct(')')?;
                Ok(Statement::Store { from, into })
            }
            t => bail!("unexpected token at statement start: {t:?}"),
        }
    }

    fn parse_program(&mut self) -> Result<Program> {
        self.expect_keyword("program")?;
        let name = self.expect_ident()?;
        self.expect_punct('{')?;
        let mut buffers = Vec::new();
        while let Some(Tok::Ident(kw)) = self.peek() {
            if kw == "block" {
                break;
            }
            let kind = BufKind::parse(kw).ok_or_else(|| anyhow!("unknown buffer kind {kw:?}"))?;
            self.pos += 1;
            let bname = self.expect_ident()?;
            let ttype = self.parse_type()?;
            buffers.push(Buffer { name: bname, kind, ttype });
        }
        let main = self.parse_block()?;
        self.expect_punct('}')?;
        Ok(Program { name, buffers, main })
    }
}

#[allow(non_snake_case)]
fn RefDir_parse(s: &str) -> Option<RefDir> {
    Some(match s {
        "in" => RefDir::In,
        "out" => RefDir::Out,
        "inout" => RefDir::InOut,
        "tmp" => RefDir::Temp,
        _ => None?,
    })
}

/// Parse a standalone block.
pub fn parse_block(src: &str) -> Result<Block> {
    let toks = tokenize(src).context("tokenize")?;
    let mut p = Parser { toks, pos: 0 };
    let b = p.parse_block()?;
    if p.pos != p.toks.len() {
        bail!("trailing tokens after block");
    }
    Ok(b)
}

/// Parse a whole program.
pub fn parse_program(src: &str) -> Result<Program> {
    let toks = tokenize(src).context("tokenize")?;
    let mut p = Parser { toks, pos: 0 };
    let prog = p.parse_program()?;
    if p.pos != p.toks.len() {
        bail!("trailing tokens after program");
    }
    Ok(prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::fig5_conv_block;
    use crate::ir::printer::{block_to_string, print_program};
    use crate::ir::program::Program;
    use crate::ir::types::DType;

    #[test]
    fn roundtrip_fig5_conv() {
        let b = fig5_conv_block();
        let text = block_to_string(&b);
        let parsed = parse_block(&text).unwrap();
        assert_eq!(parsed, b);
    }

    #[test]
    fn roundtrip_program() {
        let mut p = Program::new(
            "tiny",
            vec![
                Buffer {
                    name: "I".into(),
                    kind: BufKind::Input,
                    ttype: TensorType::contiguous(DType::I8, &[12, 16, 8]),
                },
                Buffer {
                    name: "F".into(),
                    kind: BufKind::Weight,
                    ttype: TensorType::contiguous(DType::I8, &[3, 3, 16, 8]),
                },
                Buffer {
                    name: "O".into(),
                    kind: BufKind::Output,
                    ttype: TensorType::contiguous(DType::I8, &[12, 16, 16]),
                },
            ],
        );
        p.main.stmts.push(Statement::Block(Box::new(fig5_conv_block())));
        let text = print_program(&p);
        let parsed = parse_program(&text).unwrap();
        assert_eq!(parsed, p);
    }

    #[test]
    fn parses_passed_indexes_and_tags() {
        let src = r#"
block inner #vectorize #unroll [x = 3*xo, i:3] (
    x + i - 1 >= 0
    in I[x + i - 1] i8(1):(1)
    out O[x]:add i8(1):(1)
) {
  $I = load(I)
  O = store($I)
}
"#;
        let b = parse_block(src).unwrap();
        assert_eq!(b.idxs.len(), 2);
        assert!(b.idxs[0].affine.is_some());
        assert_eq!(b.idxs[0].range, 1);
        assert!(b.has_tag("vectorize") && b.has_tag("unroll"));
        let text = block_to_string(&b);
        assert_eq!(parse_block(&text).unwrap(), b);
    }

    #[test]
    fn parses_locations() {
        let src = r#"
block tile loc(PE, bank=p) [p:4] (
    in I[p] f32(1):(1) loc(SRAM, bank=p, addr=128)
    out O[p]:assign f32(1):(1) loc(SRAM)
) {
  $I = load(I)
  O = store($I)
}
"#;
        let b = parse_block(src).unwrap();
        assert_eq!(b.location.as_ref().unwrap().unit, "PE");
        let r = b.find_ref("I").unwrap();
        let loc = r.location.as_ref().unwrap();
        assert_eq!(loc.unit, "SRAM");
        assert_eq!(loc.addr, Some(128));
        assert!(loc.bank.is_some());
        let text = block_to_string(&b);
        assert_eq!(parse_block(&text).unwrap(), b);
    }

    #[test]
    fn parses_specials_and_constants() {
        let src = r#"
block sp [] (
    in A[] f32():()
    out B[]:assign f32():()
) {
  $c = 2.5
  $n = -3.0
  special gather(A) -> (B) [axis=1]
}
"#;
        let b = parse_block(src).unwrap();
        assert_eq!(b.stmts.len(), 3);
        match &b.stmts[2] {
            Statement::Special(sp) => {
                assert_eq!(sp.name, "gather");
                assert_eq!(sp.attrs.get("axis").map(|s| s.as_str()), Some("1"));
            }
            _ => panic!("expected special"),
        }
        let text = block_to_string(&b);
        assert_eq!(parse_block(&text).unwrap(), b);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_block("block x { }").is_err()); // missing [..] ( .. )
        assert!(parse_block("blah").is_err());
        assert!(parse_block("block b [] ( x >= 1 ) { }").is_err()); // >= 1 not allowed
    }
}
