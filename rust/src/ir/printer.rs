//! Textual rendering of Stripe IR, in the style of the paper's Fig. 5.
//!
//! The format round-trips through [`super::parser`]:
//! `parse(print(p)) == p`. Grammar sketch:
//!
//! ```text
//! program    ::= "program" NAME "{" buffer* block "}"
//! buffer     ::= ("input"|"output"|"weight"|"tmp") NAME type
//! block      ::= "block" NAME tag* loc? "[" idx,* "]" "(" decl* ")" "{" stmt* "}"
//! idx        ::= NAME ":" INT | NAME "=" affine
//! decl       ::= affine ">=" "0"
//!              | ("in"|"out"|"inout"|"tmp") NAME ("as" NAME)?
//!                "[" affine,* "]" (":" agg)? type loc?
//! type       ::= dtype "(" INT,* "):(" INT,* ")"
//! stmt       ::= block
//!              | "$"NAME "=" "load" "(" NAME ")"
//!              | NAME "=" "store" "(" "$"NAME ")"
//!              | "$"NAME "=" OP "(" "$"NAME,* ")"
//!              | "$"NAME "=" NUMBER
//!              | "special" NAME "(" NAME,* ")" "->" "(" NAME,* ")" attrs?
//! loc        ::= "loc" "(" NAME ("," "bank=" affine)? ("," "addr=" INT)? ")"
//! tag        ::= "#" NAME
//! ```

use std::fmt::Write as _;

use super::block::{Block, Idx, Refinement, Statement};
use super::program::Program;

/// Pretty-print a whole program.
pub fn print_program(p: &Program) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "program {} {{", p.name);
    for b in &p.buffers {
        let _ = writeln!(s, "  {} {} {}", b.kind.name(), b.name, b.ttype);
    }
    print_block(&p.main, 1, &mut s);
    s.push_str("}\n");
    s
}

/// Pretty-print one block at the given indent depth.
pub fn print_block(b: &Block, depth: usize, out: &mut String) {
    let pad = "  ".repeat(depth);
    let _ = write!(out, "{pad}block {}", b.name);
    for t in &b.tags {
        let _ = write!(out, " #{t}");
    }
    if let Some(l) = &b.location {
        let _ = write!(out, " {l}");
    }
    let _ = write!(out, " [");
    for (i, idx) in b.idxs.iter().enumerate() {
        if i > 0 {
            let _ = write!(out, ", ");
        }
        print_idx(idx, out);
    }
    let _ = writeln!(out, "] (");
    let ipad = "  ".repeat(depth + 2);
    for c in &b.constraints {
        let _ = writeln!(out, "{ipad}{c} >= 0");
    }
    for r in &b.refs {
        print_ref(r, &ipad, out);
    }
    let _ = writeln!(out, "{pad}) {{");
    for st in &b.stmts {
        print_stmt(st, depth + 1, out);
    }
    let _ = writeln!(out, "{pad}}}");
}

fn print_idx(idx: &Idx, out: &mut String) {
    match &idx.affine {
        Some(a) => {
            let _ = write!(out, "{} = {a}", idx.name);
        }
        None => {
            let _ = write!(out, "{}:{}", idx.name, idx.range);
        }
    }
}

fn print_ref(r: &Refinement, pad: &str, out: &mut String) {
    let _ = write!(out, "{pad}{} {}", r.dir.name(), r.from);
    if r.into != r.from {
        let _ = write!(out, " as {}", r.into);
    }
    let _ = write!(out, "[");
    for (i, a) in r.access.iter().enumerate() {
        if i > 0 {
            let _ = write!(out, ", ");
        }
        let _ = write!(out, "{a}");
    }
    let _ = write!(out, "]");
    if r.dir.is_write() {
        let _ = write!(out, ":{}", r.agg.name());
    }
    let _ = write!(out, " {}", r.ttype);
    if let Some(l) = &r.location {
        let _ = write!(out, " {l}");
    }
    let _ = writeln!(out);
}

fn print_stmt(st: &Statement, depth: usize, out: &mut String) {
    let pad = "  ".repeat(depth);
    match st {
        Statement::Block(b) => print_block(b, depth, out),
        Statement::Load { from, into } => {
            let _ = writeln!(out, "{pad}{into} = load({from})");
        }
        Statement::Store { from, into } => {
            let _ = writeln!(out, "{pad}{into} = store({from})");
        }
        Statement::Intrinsic { op, inputs, output } => {
            let _ = writeln!(out, "{pad}{output} = {}({})", op.name(), inputs.join(", "));
        }
        Statement::Constant { output, value } => {
            // Always include a decimal point so the parser can tell
            // constants from idents.
            if value.fract() == 0.0 && value.abs() < 1e15 {
                let _ = writeln!(out, "{pad}{output} = {value:.1}");
            } else {
                let _ = writeln!(out, "{pad}{output} = {value}");
            }
        }
        Statement::Special(sp) => {
            let _ = write!(
                out,
                "{pad}special {}({}) -> ({})",
                sp.name,
                sp.inputs.join(", "),
                sp.outputs.join(", ")
            );
            if !sp.attrs.is_empty() {
                let attrs: Vec<String> =
                    sp.attrs.iter().map(|(k, v)| format!("{k}={v}")).collect();
                let _ = write!(out, " [{}]", attrs.join(", "));
            }
            let _ = writeln!(out);
        }
    }
}

/// Convenience: print a block standalone (depth 0).
pub fn block_to_string(b: &Block) -> String {
    let mut s = String::new();
    print_block(b, 0, &mut s);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::fig5_conv_block;

    #[test]
    fn fig5_flat_conv_prints_like_paper() {
        let b = fig5_conv_block();
        let s = block_to_string(&b);
        // Key syntactic elements of Fig. 5a:
        assert!(s.contains("block conv"));
        assert!(s.contains("x:12, y:16, i:3, j:3, c:8, k:16"));
        assert!(s.contains("i + x - 1 >= 0")); // terms render name-sorted
        assert!(s.contains("in I[i + x - 1, j + y - 1, c] i8(1, 1, 1):(128, 8, 1)"));
        assert!(s.contains("out O[x, y, k]:add i8(1, 1, 1):(256, 16, 1)"));
        assert!(s.contains("$I = load(I)"));
        assert!(s.contains("$O = mul($I, $F)"));
        assert!(s.contains("O = store($O)"));
    }

    #[test]
    fn constants_always_have_decimal_point() {
        use crate::ir::block::{Block, Statement};
        let mut b = Block::new("k");
        b.stmts.push(Statement::Constant { output: "$c".into(), value: 3.0 });
        let s = block_to_string(&b);
        assert!(s.contains("$c = 3.0"));
    }
}
