//! Whole-network programs: top-level buffers + a root block.
//!
//! A network is "a list of polyhedra" (§1.3): the root block has an
//! empty iteration space and one nested block per tensor operation. Its
//! refinements bring the program's named buffers into scope.

use super::block::{Block, RefDir, Refinement};
use super::types::TensorType;

/// Role of a top-level buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BufKind {
    /// Fed by the caller at execution time.
    Input,
    /// Read back by the caller after execution.
    Output,
    /// Trainable parameters — fed by the caller (like inputs) but
    /// distinguished for artifact bookkeeping.
    Weight,
    /// Intermediate tensors between ops.
    Temp,
}

impl BufKind {
    pub fn name(self) -> &'static str {
        match self {
            BufKind::Input => "input",
            BufKind::Output => "output",
            BufKind::Weight => "weight",
            BufKind::Temp => "tmp",
        }
    }

    pub fn parse(s: &str) -> Option<BufKind> {
        Some(match s {
            "input" => BufKind::Input,
            "output" => BufKind::Output,
            "weight" => BufKind::Weight,
            "tmp" => BufKind::Temp,
            _ => return None,
        })
    }
}

/// A top-level tensor allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct Buffer {
    pub name: String,
    pub kind: BufKind,
    pub ttype: TensorType,
}

/// A complete Stripe program.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    pub name: String,
    pub buffers: Vec<Buffer>,
    /// Root block; its statements are the network's operations in
    /// (semantically) serial order.
    pub main: Block,
}

impl Program {
    /// Create a program whose `main` block refines every buffer at zero
    /// offset with its full shape (the canonical post-lowering form).
    pub fn new(name: &str, buffers: Vec<Buffer>) -> Program {
        let mut main = Block::new("main");
        for b in &buffers {
            let dir = match b.kind {
                BufKind::Input | BufKind::Weight => RefDir::In,
                BufKind::Output => RefDir::Out,
                BufKind::Temp => RefDir::Temp,
            };
            let mut r = Refinement::new(
                dir,
                &b.name,
                Refinement::zero_access(b.ttype.rank()),
                b.ttype.clone(),
            );
            if matches!(b.kind, BufKind::Temp) {
                r.from = String::new();
            }
            main.refs.push(r);
        }
        Program { name: name.to_string(), buffers, main }
    }

    pub fn buffer(&self, name: &str) -> Option<&Buffer> {
        self.buffers.iter().find(|b| b.name == name)
    }

    pub fn buffers_of(&self, kind: BufKind) -> impl Iterator<Item = &Buffer> {
        self.buffers.iter().filter(move |b| b.kind == kind)
    }

    /// All operation blocks directly under main.
    pub fn ops(&self) -> impl Iterator<Item = &Block> {
        self.main.child_blocks()
    }

    /// Count of blocks in the whole program tree.
    pub fn block_count(&self) -> usize {
        let mut n = 0;
        self.main.walk(&mut |_| n += 1);
        n
    }

    /// Maximum nesting depth across the program.
    pub fn depth(&self) -> usize {
        self.main.depth()
    }

    /// A copy of the program with every buffer and every refinement
    /// retyped to `dtype`. Used by the CLI `--dtype` flag and the
    /// differential dtype sweep: the canned frontend networks are
    /// authored in f32, and retyping them uniformly exercises the
    /// dtype-generic storage layer without changing any topology.
    pub fn with_dtype(&self, dtype: super::types::DType) -> Program {
        let mut p = self.clone();
        for b in &mut p.buffers {
            b.ttype.dtype = dtype;
        }
        p.main.walk_mut(&mut |blk| {
            for r in &mut blk.refs {
                r.ttype.dtype = dtype;
            }
        });
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::types::DType;

    fn prog() -> Program {
        Program::new(
            "p",
            vec![
                Buffer {
                    name: "I".into(),
                    kind: BufKind::Input,
                    ttype: TensorType::contiguous(DType::F32, &[4, 4]),
                },
                Buffer {
                    name: "T".into(),
                    kind: BufKind::Temp,
                    ttype: TensorType::contiguous(DType::F32, &[4, 4]),
                },
                Buffer {
                    name: "O".into(),
                    kind: BufKind::Output,
                    ttype: TensorType::contiguous(DType::F32, &[4]),
                },
            ],
        )
    }

    #[test]
    fn main_refs_mirror_buffers() {
        let p = prog();
        assert_eq!(p.main.refs.len(), 3);
        assert_eq!(p.main.find_ref("I").unwrap().dir, RefDir::In);
        assert_eq!(p.main.find_ref("O").unwrap().dir, RefDir::Out);
        assert_eq!(p.main.find_ref("T").unwrap().dir, RefDir::Temp);
        assert_eq!(p.main.find_ref("T").unwrap().from, "");
    }

    #[test]
    fn buffer_lookup_and_kinds() {
        let p = prog();
        assert_eq!(p.buffer("I").unwrap().kind, BufKind::Input);
        assert!(p.buffer("missing").is_none());
        assert_eq!(p.buffers_of(BufKind::Temp).count(), 1);
    }

    #[test]
    fn kind_name_roundtrip() {
        for k in [BufKind::Input, BufKind::Output, BufKind::Weight, BufKind::Temp] {
            assert_eq!(BufKind::parse(k.name()), Some(k));
        }
    }
}
