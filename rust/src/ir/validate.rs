//! Semantic validation of Stripe programs.
//!
//! Three families of checks:
//!
//! 1. **Scoping** (§3.2): index names are unique; passed indexes only
//!    reference parent indexes; constraints and accesses only reference
//!    this block's indexes; refinements resolve to a parent-scope buffer
//!    with matching rank; scalars are defined before use; stores go
//!    through writable refinements.
//! 2. **Definition 2** (the parallel-polyhedral-block conditions):
//!    *assign* outputs may not be written by two distinct iterations;
//!    no iteration may read an element another iteration writes. Both
//!    are decided by `poly::overlap` over the block's iteration space,
//!    extended with "footprint" dimensions so that a refinement's whole
//!    declared view counts as touched.
//! 3. **Bounds**: composing accesses down the nest (substituting passed
//!    indexes, accumulating offsets, intersecting constraints), every
//!    *leaf* access must land inside the root buffer — this is what
//!    makes the §3.3 "round up the quotient, then constrain away the
//!    overflow" tiling rewrite checkable.

use std::collections::{BTreeMap, BTreeSet};

use crate::poly::polyhedron::Dim as PolyDim;
use crate::poly::{overlap, Affine, Polyhedron};

use super::block::{Block, RefDir, Statement};
use super::program::Program;

/// Severity of a finding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

/// One validation finding.
#[derive(Debug, Clone)]
pub struct Violation {
    pub severity: Severity,
    pub block_path: String,
    pub message: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self.severity {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        write!(f, "{s}: [{}] {}", self.block_path, self.message)
    }
}

/// A buffer view tracked down the nest.
#[derive(Debug, Clone)]
struct AbsView {
    /// Name of the root allocation this view refines.
    root: String,
    /// Logical sizes of the root allocation.
    root_sizes: Vec<u64>,
    /// Absolute per-dimension offset of this view's origin within the
    /// root, over the accumulated (uniquified) context index names.
    abs_access: Vec<Affine>,
    /// Sizes of this view.
    sizes: Vec<u64>,
}

/// Validate a whole program. Returns all findings (empty = clean).
pub fn validate_program(p: &Program) -> Vec<Violation> {
    let mut v = Validator { findings: Vec::new() };
    // Root views: main block refinements must match program buffers.
    let mut views: BTreeMap<String, AbsView> = BTreeMap::new();
    for r in &p.main.refs {
        let root_name = if r.dir == RefDir::Temp { r.into.clone() } else { r.from.clone() };
        if r.dir != RefDir::Temp && p.buffer(&r.from).is_none() {
            v.err("main", format!("refinement {:?} does not name a program buffer", r.from));
            continue;
        }
        let sizes = r.ttype.sizes();
        views.insert(
            r.into.clone(),
            AbsView {
                root: root_name,
                root_sizes: sizes.clone(),
                abs_access: vec![Affine::zero(); r.ttype.rank()],
                sizes,
            },
        );
    }
    let space = Polyhedron::default();
    let rename: BTreeMap<String, Affine> = BTreeMap::new();
    v.check_block(&p.main, "main", &space, &rename, &views);
    v.findings
}

/// Validate a standalone block against known root allocation sizes
/// (`name -> logical sizes`). Buffers not present in `roots` get
/// unbounded upper extents (only lower-bound violations are checkable).
pub fn validate_block_rooted(b: &Block, roots: &BTreeMap<String, Vec<u64>>) -> Vec<Violation> {
    let mut v = Validator { findings: Vec::new() };
    let mut views = BTreeMap::new();
    for r in &b.refs {
        let root_sizes = roots
            .get(&r.from)
            .cloned()
            .unwrap_or_else(|| vec![UNKNOWN_EXTENT; r.ttype.rank()]);
        views.insert(
            r.into.clone(),
            AbsView {
                root: r.from.clone(),
                root_sizes,
                abs_access: vec![Affine::zero(); r.ttype.rank()],
                sizes: r.ttype.sizes(),
            },
        );
    }
    // The block itself is checked as a child of an empty context, so its
    // own refinements are re-resolved against `views` by name.
    let mut ctx = Polyhedron::default();
    let mut rename = BTreeMap::new();
    v.enter_and_check(b, "root", &mut ctx, &mut rename, &views, true);
    v.findings
}

/// Validate a standalone block with no root size information.
pub fn validate_block(b: &Block) -> Vec<Violation> {
    validate_block_rooted(b, &BTreeMap::new())
}

/// Sentinel for "allocation extent unknown" in standalone validation.
const UNKNOWN_EXTENT: u64 = (i64::MAX >> 2) as u64;

struct Validator {
    findings: Vec<Violation>,
}

impl Validator {
    fn err(&mut self, path: &str, message: String) {
        self.findings.push(Violation {
            severity: Severity::Error,
            block_path: path.to_string(),
            message,
        });
    }

    #[allow(dead_code)] // reserved for non-fatal findings
    fn warn(&mut self, path: &str, message: String) {
        self.findings.push(Violation {
            severity: Severity::Warning,
            block_path: path.to_string(),
            message,
        });
    }

    /// Check `b` whose refinements resolve against `parent_views`, with
    /// the accumulated outer iteration space `space` / rename map.
    fn check_block(
        &mut self,
        b: &Block,
        path: &str,
        space: &Polyhedron,
        parent_rename: &BTreeMap<String, Affine>,
        parent_views: &BTreeMap<String, AbsView>,
    ) {
        let mut ctx = space.clone();
        let mut rename = parent_rename.clone();
        self.enter_and_check(b, path, &mut ctx, &mut rename, parent_views, false)
    }

    /// Shared body: extend the context with `b`'s indexes, run all
    /// per-block checks, then recurse.
    fn enter_and_check(
        &mut self,
        b: &Block,
        path: &str,
        ctx: &mut Polyhedron,
        rename: &mut BTreeMap<String, Affine>,
        parent_views: &BTreeMap<String, AbsView>,
        is_root: bool,
    ) {
        // --- scoping: index uniqueness
        let mut seen = BTreeSet::new();
        for idx in &b.idxs {
            if !seen.insert(idx.name.clone()) {
                self.err(path, format!("duplicate index name {:?}", idx.name));
            }
        }
        // Parent index names (what passed idxs may reference).
        let parent_names: BTreeSet<String> = rename.keys().cloned().collect();

        // --- extend context space; build this block's rename map
        let mut new_rename: BTreeMap<String, Affine> = BTreeMap::new();
        for idx in &b.idxs {
            match &idx.affine {
                None => {
                    let unique = unique_name(&idx.name, ctx);
                    ctx.dims.push(PolyDim { name: unique.clone(), range: idx.range });
                    new_rename.insert(idx.name.clone(), Affine::var(&unique));
                }
                Some(a) => {
                    if idx.range != 1 {
                        self.err(
                            path,
                            format!("passed index {:?} must have range 1", idx.name),
                        );
                    }
                    for v in a.vars() {
                        if !parent_names.contains(v) {
                            self.err(
                                path,
                                format!(
                                    "passed index {:?} references {:?}, not a parent index",
                                    idx.name, v
                                ),
                            );
                        }
                    }
                    new_rename.insert(idx.name.clone(), a.substitute(rename));
                }
            }
        }
        let local_names: BTreeSet<String> = b.idxs.iter().map(|i| i.name.clone()).collect();

        // --- scoping: constraints and accesses use only local indexes
        for c in &b.constraints {
            for v in c.vars() {
                if !local_names.contains(v) {
                    self.err(path, format!("constraint references {v:?}, not a block index"));
                }
            }
            ctx.constraints.push(c.substitute(&new_rename));
        }
        for r in &b.refs {
            for a in &r.access {
                for v in a.vars() {
                    if !local_names.contains(v) {
                        self.err(
                            path,
                            format!(
                                "refinement {:?} access references {v:?}, not a block index",
                                r.into
                            ),
                        );
                    }
                }
            }
        }

        // --- resolve refinements into views
        let mut views: BTreeMap<String, AbsView> = BTreeMap::new();
        for r in &b.refs {
            if r.dir == RefDir::Temp {
                let sizes = r.ttype.sizes();
                views.insert(
                    r.into.clone(),
                    AbsView {
                        root: format!("{path}/{}", r.into),
                        root_sizes: sizes.clone(),
                        abs_access: vec![Affine::zero(); r.ttype.rank()],
                        sizes,
                    },
                );
                continue;
            }
            // Root blocks resolve against the prepared allocation views
            // keyed by their own `into` names.
            let key = if is_root { &r.into } else { &r.from };
            let Some(pv) = parent_views.get(key) else {
                self.err(
                    path,
                    format!("refinement {:?}: no buffer {:?} in parent scope", r.into, r.from),
                );
                continue;
            };
            if pv.sizes.len() != r.ttype.rank() || r.access.len() != r.ttype.rank() {
                self.err(
                    path,
                    format!(
                        "refinement {:?}: rank mismatch (parent {} vs child {} / access {})",
                        r.into,
                        pv.sizes.len(),
                        r.ttype.rank(),
                        r.access.len()
                    ),
                );
                continue;
            }
            // Root or not, the view origin is the parent origin plus
            // this refinement's (renamed) access.
            let abs_access: Vec<Affine> = pv
                .abs_access
                .iter()
                .zip(&r.access)
                .map(|(base, a)| base.add(&a.substitute(&new_rename)))
                .collect();
            views.insert(
                r.into.clone(),
                AbsView {
                    root: pv.root.clone(),
                    root_sizes: pv.root_sizes.clone(),
                    abs_access,
                    sizes: r.ttype.sizes(),
                },
            );
        }

        // --- statement checks + leaf bounds
        let has_child_blocks = b.stmts.iter().any(|s| matches!(s, Statement::Block(_)));
        self.check_statements(b, path, &views);
        if !has_child_blocks && !b.stmts.is_empty() {
            self.check_leaf_bounds(b, path, ctx, &views);
        }

        // --- Definition-2 conditions on this block
        self.check_def2(b, path);

        // --- recurse
        for (i, st) in b.stmts.iter().enumerate() {
            if let Statement::Block(cb) = st {
                let child_path = format!("{path}/{}[{i}]", cb.name);
                self.check_block(cb, &child_path, ctx, &new_rename, &views);
            }
        }
    }

    fn check_statements(&mut self, b: &Block, path: &str, views: &BTreeMap<String, AbsView>) {
        let mut defined: BTreeSet<String> = BTreeSet::new();
        for st in &b.stmts {
            match st {
                Statement::Load { from, into } => {
                    match b.find_ref(from) {
                        None => self.err(path, format!("load from undeclared buffer {from:?}")),
                        Some(r) if !r.dir.is_read() && r.dir != RefDir::Temp => {
                            self.err(path, format!("load from non-readable refinement {from:?}"))
                        }
                        _ => {}
                    }
                    if views.get(from).is_none() && b.find_ref(from).is_some() {
                        // refinement failed to resolve earlier; already reported
                    }
                    defined.insert(into.clone());
                }
                Statement::Store { from, into } => {
                    if !defined.contains(from) {
                        self.err(path, format!("store of undefined scalar {from:?}"));
                    }
                    match b.find_ref(into) {
                        None => self.err(path, format!("store to undeclared buffer {into:?}")),
                        Some(r) if !r.dir.is_write() && r.dir != RefDir::Temp => {
                            self.err(path, format!("store to non-writable refinement {into:?}"))
                        }
                        _ => {}
                    }
                }
                Statement::Intrinsic { op, inputs, output } => {
                    if inputs.len() != op.arity() {
                        self.err(
                            path,
                            format!(
                                "intrinsic {} expects {} args, got {}",
                                op.name(),
                                op.arity(),
                                inputs.len()
                            ),
                        );
                    }
                    for i in inputs {
                        if !defined.contains(i) {
                            self.err(path, format!("intrinsic uses undefined scalar {i:?}"));
                        }
                    }
                    defined.insert(output.clone());
                }
                Statement::Constant { output, .. } => {
                    defined.insert(output.clone());
                }
                Statement::Special(sp) => {
                    for i in sp.inputs.iter().chain(&sp.outputs) {
                        if b.find_ref(i).is_none() {
                            self.err(
                                path,
                                format!("special {} references undeclared buffer {i:?}", sp.name),
                            );
                        }
                    }
                }
                Statement::Block(_) => {}
            }
        }
    }

    /// Leaf blocks: every access (view origin + footprint) must stay
    /// within the root allocation for all context points.
    fn check_leaf_bounds(
        &mut self,
        b: &Block,
        path: &str,
        ctx: &Polyhedron,
        views: &BTreeMap<String, AbsView>,
    ) {
        for r in &b.refs {
            let Some(view) = views.get(&r.into) else { continue };
            let ineqs = ctx.to_inequalities();
            let names = ctx.names();
            for (d, acc) in view.abs_access.iter().enumerate() {
                // Constant accesses are cheap to check directly.
                let extent = view.sizes[d] as i64 - 1;
                if acc.is_constant() {
                    let lo = acc.offset;
                    let hi = acc.offset + extent;
                    if lo < 0 || hi >= view.root_sizes[d] as i64 {
                        self.err(
                            path,
                            format!(
                                "refinement {:?} dim {d}: access [{lo}, {hi}] outside root 0..{}",
                                r.into, view.root_sizes[d]
                            ),
                        );
                    }
                    continue;
                }
                // Bounds of the affine over the context polyhedron.
                let mut sys = ineqs.clone();
                // Introduce t = acc as a fresh variable via two inequalities.
                let t = "___t";
                let mut names2 = names.clone();
                names2.push(t.to_string());
                let mut eq1 = acc.clone();
                eq1.add_term(t, -1);
                sys.push(eq1.clone());
                sys.push(eq1.scale(-1));
                match crate::poly::fm::variable_bounds(&sys, &names2, t) {
                    None => { /* empty context — vacuously in bounds */ }
                    Some((lo, hi)) => {
                        let lo = lo.unwrap_or(i64::MIN);
                        let hi = hi.unwrap_or(i64::MAX).saturating_add(extent);
                        if lo < 0 || hi >= view.root_sizes[d] as i64 {
                            self.err(
                                path,
                                format!(
                                    "refinement {:?} dim {d}: access range [{lo}, {hi}] can leave root 0..{}",
                                    r.into, view.root_sizes[d]
                                ),
                            );
                        }
                    }
                }
            }
        }
    }

    /// Definition-2 conditions over this block's own iteration space.
    fn check_def2(&mut self, b: &Block, path: &str) {
        let base_space = b.iteration_space();
        // Resolve which refinements share an underlying parent buffer:
        // within one block, same `from` ⇒ same parent view.
        for (wi, w) in b.refs.iter().enumerate() {
            if !w.dir.is_write() {
                continue;
            }
            let (w_space, w_access) = extend_with_footprint(&base_space, w, "w");
            if w.agg == super::block::AggOp::Assign {
                let ov = overlap::distinct_iteration_overlap(
                    &w_space,
                    &w_access,
                    &w_access,
                    &w.ttype.strides(),
                );
                if ov.may_conflict() {
                    self.err(
                        path,
                        format!(
                            "assign-aggregated output {:?} written by multiple iterations ({ov:?})",
                            w.into
                        ),
                    );
                }
            }
            for (ri, r) in b.refs.iter().enumerate() {
                if !r.dir.is_read() || r.from != w.from || ri == wi {
                    continue;
                }
                // Combined space: block idxs + both footprints.
                let (mut space, w_acc) = extend_with_footprint(&base_space, w, "w");
                let (r_space, r_acc) = extend_with_footprint(&base_space, r, "r");
                for d in r_space.dims.iter().skip(base_space.dims.len()) {
                    space.dims.push(d.clone());
                }
                let ov = overlap::distinct_iteration_overlap(
                    &space,
                    &w_acc,
                    &r_acc,
                    &w.ttype.strides(),
                );
                if ov.may_conflict() {
                    self.err(
                        path,
                        format!(
                            "iteration writes {:?} while another iteration reads {:?} ({ov:?})",
                            w.into, r.into
                        ),
                    );
                }
            }
        }
    }
}

/// Extend an iteration space with footprint dims (one per view dimension
/// of size > 1) and return the effective per-element access vector.
/// Shared with `exec::parallel`, whose disjointness analysis must use
/// exactly this construction to inherit the validator's soundness
/// argument.
pub(crate) fn extend_with_footprint(
    space: &Polyhedron,
    r: &super::block::Refinement,
    tag: &str,
) -> (Polyhedron, Vec<Affine>) {
    let mut s = space.clone();
    let mut access = Vec::with_capacity(r.access.len());
    for (d, a) in r.access.iter().enumerate() {
        let size = r.ttype.dims[d].size;
        if size > 1 {
            let name = format!("__fp_{tag}{d}");
            s.dims.push(PolyDim { name: name.clone(), range: size });
            access.push(a.add(&Affine::var(&name)));
        } else {
            access.push(a.clone());
        }
    }
    (s, access)
}

fn unique_name(base: &str, ctx: &Polyhedron) -> String {
    if !ctx.dims.iter().any(|d| d.name == base) {
        return base.to_string();
    }
    let mut i = 1;
    loop {
        let cand = format!("{base}__{i}");
        if !ctx.dims.iter().any(|d| d.name == cand) {
            return cand;
        }
        i += 1;
    }
}

/// True if no `Error`-severity findings are present.
pub fn is_valid(findings: &[Violation]) -> bool {
    findings.iter().all(|f| f.severity != Severity::Error)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::fig5_conv_block;
    use crate::ir::block::{AggOp, Idx, Refinement, Statement};
    use crate::ir::types::{DType, TensorType};

    #[test]
    fn fig5_conv_is_valid() {
        let b = fig5_conv_block();
        let f = validate_block(&b);
        assert!(is_valid(&f), "{f:?}");
    }

    #[test]
    fn assign_with_reduction_idx_is_flagged() {
        // O[x] assigned over (x, c) — c iterations collide.
        let t = TensorType::contiguous(DType::F32, &[4]);
        let mut b = crate::ir::builder::contraction(
            "bad",
            &[("x", 4), ("c", 3)],
            vec![],
            crate::ir::builder::Operand::new("O", vec![Affine::var("x")], &t),
            AggOp::Assign,
            &[crate::ir::builder::Operand::new("I", vec![Affine::var("x")], &t)],
            crate::ir::block::IntrOp::Mul,
        );
        b.name = "bad".into();
        let f = validate_block(&b);
        assert!(!is_valid(&f), "expected a Def-2 violation");
        assert!(f.iter().any(|v| v.message.contains("assign-aggregated")));
    }

    #[test]
    fn undefined_scalar_store_flagged() {
        let t = TensorType::contiguous(DType::F32, &[4]);
        let mut b = crate::ir::block::Block::new("b");
        b.idxs.push(Idx::range("x", 4));
        b.refs.push(Refinement::new(
            RefDir::Out,
            "O",
            vec![Affine::var("x")],
            crate::ir::builder::scalar_view(&t),
        ));
        b.stmts.push(Statement::Store { from: "$nope".into(), into: "O".into() });
        let f = validate_block(&b);
        assert!(f.iter().any(|v| v.message.contains("undefined scalar")));
    }

    #[test]
    fn constraint_variable_scope_checked() {
        let mut b = fig5_conv_block();
        b.constraints.push(Affine::var("not_an_idx"));
        let f = validate_block(&b);
        assert!(f.iter().any(|v| v.message.contains("not a block index")));
    }

    #[test]
    fn out_of_bounds_leaf_access_flagged() {
        // Access x + 2 over x:4 into a root of size 4 → max 5, OOB.
        let t = TensorType::contiguous(DType::F32, &[4]);
        let b = crate::ir::builder::contraction(
            "oob",
            &[("x", 4)],
            vec![],
            crate::ir::builder::Operand::new("O", vec![Affine::var("x")], &t),
            AggOp::Assign,
            &[crate::ir::builder::Operand::new(
                "I",
                vec![Affine::from_terms(&[("x", 1)], 2)],
                &t,
            )],
            crate::ir::block::IntrOp::Mul,
        );
        let roots: BTreeMap<String, Vec<u64>> =
            [("I".to_string(), vec![4u64]), ("O".to_string(), vec![4u64])].into();
        let f = validate_block_rooted(&b, &roots);
        assert!(!is_valid(&f));
        assert!(f.iter().any(|v| v.message.contains("dim 0")), "{f:?}");
    }

    #[test]
    fn negative_access_flagged_without_roots() {
        // Access x - 1 can reach -1; lower bound is checkable even with
        // unknown allocation extents.
        let t = TensorType::contiguous(DType::F32, &[4]);
        let b = crate::ir::builder::contraction(
            "neg",
            &[("x", 4)],
            vec![],
            crate::ir::builder::Operand::new("O", vec![Affine::var("x")], &t),
            AggOp::Assign,
            &[crate::ir::builder::Operand::new(
                "I",
                vec![Affine::from_terms(&[("x", 1)], -1)],
                &t,
            )],
            crate::ir::block::IntrOp::Mul,
        );
        let f = validate_block(&b);
        assert!(!is_valid(&f), "{f:?}");
    }

    #[test]
    fn warning_does_not_invalidate() {
        let v = vec![Violation {
            severity: Severity::Warning,
            block_path: "x".into(),
            message: "hmm".into(),
        }];
        assert!(is_valid(&v));
    }
}
