//! `stripe` — the command-line driver.
//!
//! ```text
//! stripe targets                         list built-in hardware targets
//! stripe compile  --target T [--tile f]  compile a canned or .tile network, print IR + report
//! stripe run      --target T [--tune]    compile + execute on random inputs, print outputs
//! stripe tune     --target T             autotune, print the decision, check service caching
//! stripe validate <file.stripe>          parse + validate a textual Stripe program
//! stripe fig1..fig5                      regenerate the paper's figures
//! stripe serve    --workers N            demo the multi-tenant serving tier, reconcile metrics
//! stripe store    stats|gc --store-dir D inspect or collect the persistent artifact store
//! ```

use stripe::coordinator::effort::{render_table, Scenario};
use stripe::coordinator::{
    compile_network, compile_network_tuned, compile_network_tuned_subgraph, ArtifactStore,
    CompileService, Counter, RequestOptions, ServeConfig, Server, StoreOutcome, TuneOptions,
};
use stripe::frontend::ops;
use stripe::hw::targets;
use stripe::ir::printer::print_program;
use stripe::util::cli::Args;

const VALUE_OPTS: &[&str] = &[
    "target", "net", "workers", "seed", "set", "tile", "kernels", "archs", "versions", "shapes",
    "engine", "dtype", "queue-depth", "tenant-cap", "cache-bytes", "deadline-ms", "store-dir",
    "store-budget", "shards", "link-gbps",
];

fn main() {
    let args = Args::from_env(VALUE_OPTS);
    let cmd = args.positional().first().cloned().unwrap_or_else(|| "help".to_string());
    let code = match cmd.as_str() {
        "targets" => cmd_targets(),
        "compile" => cmd_compile(&args),
        "run" => cmd_run(&args),
        "tune" => cmd_tune(&args),
        "validate" => cmd_validate(&args),
        "fig1" => cmd_fig1(&args),
        "fig2" => figs::fig2(),
        "fig3" => figs::fig3(),
        "fig4" => figs::fig4(),
        "fig5" => figs::fig5(),
        "serve" => cmd_serve(&args),
        "store" => cmd_store(&args),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "stripe — Tensor Compilation via the Nested Polyhedral Model (reproduction)\n\
         \n\
         Usage: stripe <command> [options]\n\
         \n\
         Commands:\n\
         \x20 targets                      list built-in hardware targets\n\
         \x20 compile --target <t>         compile a network, print pass report (+ --print for IR)\n\
         \x20         --net <name|f.tile>  canned: fig4_conv, conv_relu, cnn, mlp, matmul\n\
         \x20         --set <path=value>   override a config parameter (Fig.1 set_config_params)\n\
         \x20         --dtype <d>          retype buffers: f32 | f64 | i32 | i8 (quantized)\n\
         \x20         --tune               search pass-pipeline variants via the cost models\n\
         \x20 run     --target <t>         compile + execute on seeded random inputs\n\
         \x20         --engine <e>         naive | planned | kernel | dataflow (inter-op DAG)\n\
         \x20         --dtype <d>          retype buffers: f32 | f64 | i32 | i8 (quantized)\n\
         \x20         --parallel           execute across the target's compute units\n\
         \x20         --workers <n>        explicit worker count (overrides --parallel)\n\
         \x20         --tune               compile through the pipeline autotuner\n\
         \x20         --simd-check         kernel engine: assert coverage >= 80% and that the\n\
         \x20                              chunked SIMD kernels beat the scalar lane baseline\n\
         \x20         --dataflow-check     dataflow engine: assert bit-equality with the serial\n\
         \x20                              plan and O(1) pool thread spawns across repeat runs\n\
         \x20         --shards <t1,t2,..>  sharded engine: split the network across several\n\
         \x20                              simulated targets (comma-separated target names),\n\
         \x20                              each region compiled for its own shard\n\
         \x20         --link-gbps <g>      inter-shard link bandwidth (default 16 GB/s)\n\
         \x20         --shard-check        sharded engine: assert bit-equality with the serial\n\
         \x20                              plan and runtime == predicted transfer bytes\n\
         \x20 tune    --target <t>         autotune a network, print the tuning decision, and\n\
         \x20         --net <name|f.tile>  verify the tuned artifact is cached by the service\n\
         \x20         --require-warm       with --store-dir: fail unless the compile was served\n\
         \x20                              from the store with zero tuning work\n\
         \x20 validate <file.stripe>       parse + validate textual Stripe\n\
         \x20 fig1 [--kernels K ...]       engineering-effort comparison table\n\
         \x20 fig2|fig3|fig4|fig5          regenerate the paper's figures\n\
         \x20 serve   --workers <n>        multi-tenant serving-tier demo (admission + cache)\n\
         \x20         --queue-depth <n>    bounded global queue (default 64)\n\
         \x20         --tenant-cap <n>     per-tenant in-flight cap (default 4, 0 = unlimited)\n\
         \x20         --cache-bytes <n>    artifact-cache LRU byte budget (0 = unlimited)\n\
         \x20         --deadline-ms <n>    request deadline (0 = none)\n\
         \x20         --metrics            print the Prometheus-style scrape\n\
         \x20 store   stats|gc             inspect or collect a persistent store directory\n\
         \n\
         Persistent store (compile | run | tune | serve | store):\n\
         \x20 --store-dir <dir>            disk tier under the in-memory cache: compiles and\n\
         \x20                              per-subgraph tuning records persist across restarts\n\
         \x20                              and are shared by concurrent processes\n\
         \x20 --store-budget <bytes>       GC byte budget for the store (0 = unlimited)\n"
    );
}

fn load_net(args: &Args) -> Result<stripe::ir::Program, String> {
    let net = args.get_or("net", "fig4_conv");
    let p = if net.ends_with(".tile") {
        let src = std::fs::read_to_string(net).map_err(|e| format!("read {net}: {e}"))?;
        let f = stripe::frontend::parse_function(&src).map_err(|e| e.to_string())?;
        stripe::frontend::lower_function(&f).map_err(|e| e.to_string())?
    } else {
        match net {
            "fig4_conv" => ops::fig4_conv_program(),
            "conv_relu" => ops::conv_relu_program(),
            "cnn" => ops::cnn_program(),
            "mlp" => ops::tiny_mlp_program(16, 32, 10),
            "matmul" => ops::matmul_program(16, 16, 16),
            other => return Err(format!("unknown net {other:?}")),
        }
    };
    // --dtype retypes every program buffer (and its refinements) before
    // compilation; the dtype lands in the schedule summary and the
    // compile-cache key.
    match args.get("dtype") {
        None => Ok(p),
        Some(name) => {
            let dt = stripe::ir::DType::parse(name)
                .ok_or_else(|| format!("unknown dtype {name:?} (f32|f64|i32|i8)"))?;
            Ok(p.with_dtype(dt))
        }
    }
}

fn load_target(args: &Args) -> Result<stripe::hw::MachineConfig, String> {
    let t = args.get_or("target", "paper_fig4");
    let mut cfg = targets::target_by_name(t).ok_or_else(|| format!("unknown target {t:?}"))?;
    if let Some(kv) = args.get("set") {
        let (path, value) = kv
            .split_once('=')
            .ok_or_else(|| format!("--set expects path=value, got {kv:?}"))?;
        let v: f64 = value.parse().map_err(|_| format!("bad value {value:?}"))?;
        cfg.set_param(path, v)?;
    }
    Ok(cfg)
}

fn cmd_targets() -> i32 {
    for t in targets::builtin_targets() {
        println!(
            "{:<12} memories: {:<28} compute: {:<18} passes: {}",
            t.name,
            t.memories
                .iter()
                .map(|m| format!("{}({}K)", m.name, m.capacity_bytes >> 10))
                .collect::<Vec<_>>()
                .join(" > "),
            t.compute
                .iter()
                .map(|c| format!("{}x{}", c.count, c.name))
                .collect::<Vec<_>>()
                .join(","),
            t.passes.iter().map(|p| p.name()).collect::<Vec<_>>().join(",")
        );
    }
    0
}

/// `--store-dir <dir>` arms the persistent artifact store (created if
/// missing); `--store-budget <bytes>` sets its post-write GC budget
/// (0 = never auto-collected).
fn open_store(args: &Args) -> Result<Option<std::sync::Arc<ArtifactStore>>, String> {
    match args.get("store-dir") {
        None => Ok(None),
        Some(dir) => {
            let store = ArtifactStore::open_with_budget(dir, args.get_u64("store-budget", 0))?;
            Ok(Some(std::sync::Arc::new(store)))
        }
    }
}

/// Two-tier compile for the direct (service-less) CLI paths: probe the
/// store under the same salted request key the service uses, fall back
/// to a fresh compile — through the store-backed subgraph tuner when
/// tuning, so repeated layer shapes share one search — and write the
/// result back.
fn compile_with_store(
    p: &stripe::ir::Program,
    cfg: &stripe::hw::MachineConfig,
    verify: bool,
    tune: bool,
    store: Option<&ArtifactStore>,
) -> Result<stripe::coordinator::CompiledNetwork, String> {
    let key = stripe::coordinator::service::fingerprint(p, cfg, verify, tune, None);
    if let Some(s) = store {
        match s.load_artifact(key) {
            StoreOutcome::Hit(net) => {
                println!("store: artifact hit for key {key:016x} in {}", s.dir().display());
                return Ok(net);
            }
            StoreOutcome::Miss => {}
            StoreOutcome::Corrupt(reason) => {
                println!("store: evicted corrupt entry ({reason}); recompiling");
            }
        }
    }
    let c = if tune {
        let opts = TuneOptions { verify, ..TuneOptions::default() };
        match store {
            Some(s) => compile_network_tuned_subgraph(p, cfg, &opts, Some(s))?,
            None => compile_network_tuned(p, cfg, &opts)?,
        }
    } else {
        compile_network(p, cfg, verify)?
    };
    if let Some(s) = store {
        if s.save_artifact(key, &c)? {
            if let Some(gc) = s.maybe_gc() {
                if gc.evicted > 0 {
                    println!(
                        "store: gc evicted {} entr(ies) / {} B",
                        gc.evicted, gc.evicted_bytes
                    );
                }
            }
        }
    }
    Ok(c)
}

fn cmd_compile(args: &Args) -> i32 {
    let run = || -> Result<(), String> {
        let p = load_net(args)?;
        let cfg = load_target(args)?;
        let verify = !args.flag("no-verify");
        let store = open_store(args)?;
        let c = compile_with_store(&p, &cfg, verify, args.flag("tune"), store.as_deref())?;
        println!("{}", c.summary());
        if args.flag("print") {
            println!("{}", print_program(&c.program));
        }
        Ok(())
    };
    report(run())
}

fn cmd_run(args: &Args) -> i32 {
    let run = || -> Result<(), String> {
        let p = load_net(args)?;
        let cfg = load_target(args)?;
        // --shards owns its own compile: each region is compiled against
        // its shard's target, so the single-target pipeline below never
        // runs for the sharded engine.
        if let Some(spec) = args.get("shards") {
            let mut topo = stripe::hw::ShardTopology::parse(spec)?;
            if let Some(g) = args.get("link-gbps") {
                let gbps: f64 =
                    g.parse().map_err(|_| format!("bad --link-gbps value {g:?}"))?;
                if gbps <= 0.0 {
                    return Err(format!("--link-gbps must be positive, got {gbps}"));
                }
                topo.link = stripe::cost::LinkModel::with_gbps(gbps);
            }
            let topo = std::sync::Arc::new(topo);
            let inputs = stripe::passes::equiv::gen_inputs(&p, args.get_u64("seed", 42));
            if args.flag("shard-check") {
                return shard_check(&p, &inputs, &topo);
            }
            return run_sharded(&p, &inputs, &topo, args.flag("tune"));
        }
        let store = open_store(args)?;
        let c = compile_with_store(&p, &cfg, false, args.flag("tune"), store.as_deref())?;
        // Schedule summary: the tile-search telemetry behind the
        // compiled pipeline, and the tuning decision when --tune.
        if let Some(st) = c.search_stats() {
            println!("{}", st.summary_line());
        }
        if let Some(t) = &c.tuning {
            print!("{}", t.summary());
        }
        let seed = args.get_u64("seed", 42);
        let inputs = stripe::passes::equiv::gen_inputs(&c.program, seed);
        if args.flag("simd-check") {
            return simd_check(&c.program, &inputs);
        }
        if args.flag("dataflow-check") {
            let units = match args.get_usize("workers", 0) {
                0 => cfg.compute_units.max(2),
                w => w.max(1),
            };
            return dataflow_check(&c.program, &inputs, units);
        }
        let engine_name = args.get_or("engine", "planned");
        let engine = stripe::exec::Engine::parse(engine_name)
            .ok_or_else(|| format!("unknown engine {engine_name:?} (naive|planned|kernel|dataflow)"))?;
        // --workers N overrides; --parallel uses the target's
        // compute-unit count; default stays serial (the always-available
        // fallback for bisection).
        let workers = match args.get_usize("workers", 0) {
            0 if args.flag("parallel") => cfg.compute_units,
            w => w.max(1),
        };
        let t0 = std::time::Instant::now();
        let out = if workers > 1 || engine == stripe::exec::Engine::Dataflow {
            let opts = stripe::exec::ExecOptions {
                workers,
                engine,
                ..stripe::exec::ExecOptions::default()
            };
            let (out, schedule) = stripe::coordinator::run_network_with(&c, &inputs, &opts)?;
            println!(
                "parallel schedule ({workers} workers, engine {}, {}/{} ops parallel):\n{}",
                engine.name(),
                schedule.parallel_ops(),
                schedule.ops.len(),
                schedule.summary()
            );
            println!(
                "fork traffic {} B (copy-on-write materialization), \
                 merge traffic {} B",
                schedule.fork_bytes(),
                schedule.merge_bytes()
            );
            if let Some(cov) = schedule.kernel_coverage() {
                println!("kernel coverage: {:.1}% of leaf iterations", cov * 100.0);
            }
            out
        } else if engine == stripe::exec::Engine::Kernel {
            let (out, report) = stripe::exec::run_program_kernel(
                &c.program,
                &inputs,
                &stripe::exec::ExecOptions { engine, ..stripe::exec::ExecOptions::default() },
            )
            .map_err(|e| e.to_string())?;
            println!("kernel coverage per op:\n{}", report.summary());
            if let Some(cov) = report.coverage() {
                println!("kernel coverage: {:.1}% of leaf iterations", cov * 100.0);
            }
            out
        } else {
            let opts =
                stripe::exec::ExecOptions { engine, ..stripe::exec::ExecOptions::default() };
            stripe::exec::run_program_with(&c.program, &inputs, &opts)
                .map_err(|e| e.to_string())?
        };
        let dt = t0.elapsed();
        for (name, vals) in &out {
            let preview: Vec<String> = vals.iter().take(8).map(|v| format!("{v:.4}")).collect();
            println!("{name}[{}] = [{} ...]", vals.len(), preview.join(", "));
        }
        println!("executed in {dt:?}");
        Ok(())
    };
    report(run())
}

/// Run the compiled program through the kernel engine `reps` times
/// with the chunked SIMD kernels toggled by `simd`, returning the
/// median wall time, the reported kernel coverage, and the outputs of
/// the final run.
fn time_kernel_engine(
    program: &stripe::ir::Program,
    inputs: &std::collections::BTreeMap<String, Vec<f32>>,
    reps: usize,
    simd: bool,
) -> Result<
    (std::time::Duration, Option<f64>, std::collections::BTreeMap<String, Vec<f32>>),
    String,
> {
    let opts = stripe::exec::ExecOptions {
        engine: stripe::exec::Engine::Kernel,
        simd,
        ..stripe::exec::ExecOptions::default()
    };
    let mut times = Vec::with_capacity(reps);
    let mut last = None;
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let r = stripe::exec::run_program_kernel(program, inputs, &opts)
            .map_err(|e| e.to_string())?;
        times.push(t0.elapsed());
        last = Some(r);
    }
    times.sort();
    let (out, report) = last.ok_or("simd-check needs at least one rep")?;
    Ok((times[times.len() / 2], report.coverage(), out))
}

/// `--simd-check`: execute the kernel engine with the chunked SIMD
/// kernels on and off over identical inputs, then require (a) bitwise
/// identical outputs, (b) kernel coverage of at least 80% of leaf
/// iterations, and (c) a median speedup over the scalar lane baseline.
/// Exits nonzero on any failure — `scripts/verify.sh` runs this per
/// storage dtype as the `VERIFY_SIMD_SMOKE` gate.
fn simd_check(
    program: &stripe::ir::Program,
    inputs: &std::collections::BTreeMap<String, Vec<f32>>,
) -> Result<(), String> {
    const REPS: usize = 30;
    let (t_simd, cov, out_simd) = time_kernel_engine(program, inputs, REPS, true)?;
    let (t_scalar, _, out_scalar) = time_kernel_engine(program, inputs, REPS, false)?;
    if out_simd != out_scalar {
        return Err("simd-check: SIMD and scalar lane paths disagree".into());
    }
    let cov = cov.ok_or("simd-check: kernel engine reported no coverage")?;
    let speedup = t_scalar.as_secs_f64() / t_simd.as_secs_f64().max(1e-12);
    println!(
        "simd-check: coverage {:.1}%, median {t_simd:?} (simd) vs {t_scalar:?} (scalar), \
         speedup {speedup:.2}x",
        cov * 100.0
    );
    if cov < 0.8 {
        return Err(format!("simd-check: kernel coverage {:.1}% below 80%", cov * 100.0));
    }
    if speedup <= 1.0 {
        return Err(format!("simd-check: no speedup over the scalar lane baseline ({speedup:.2}x)"));
    }
    Ok(())
}

/// `--dataflow-check`: execute the program serially through the plan
/// engine and through the inter-op dataflow scheduler over identical
/// inputs, then require (a) bitwise identical outputs, (b) a
/// persistent worker pool — thread spawns stay O(1) across repeat runs
/// instead of O(ops) — and (c) a non-degenerate DAG report. Exits
/// nonzero on any failure — `scripts/verify.sh` runs this as the
/// `VERIFY_DATAFLOW_SMOKE` gate.
fn dataflow_check(
    program: &stripe::ir::Program,
    inputs: &std::collections::BTreeMap<String, Vec<f32>>,
    workers: usize,
) -> Result<(), String> {
    const REPS: usize = 3;
    let serial = stripe::exec::run_program_with(
        program,
        inputs,
        &stripe::exec::ExecOptions::default(),
    )
    .map_err(|e| e.to_string())?;
    let pool = stripe::exec::ComputePool::new(workers);
    let opts = stripe::exec::ExecOptions {
        engine: stripe::exec::Engine::Dataflow,
        workers,
        compute: Some(pool.clone()),
        ..stripe::exec::ExecOptions::default()
    };
    let mut last = None;
    for _ in 0..REPS {
        let r = stripe::exec::run_program_dataflow(program, inputs, &opts)
            .map_err(|e| e.to_string())?;
        last = Some(r);
    }
    let (out, schedule) = last.ok_or("dataflow-check needs at least one rep")?;
    if out != serial {
        return Err("dataflow-check: dataflow and serial plan outputs disagree".into());
    }
    let dag = schedule.dag.as_ref().ok_or("dataflow-check: scheduler reported no DAG stats")?;
    println!("dataflow-check: {}", dag.summary_line());
    if dag.dag_ops == 0 || dag.critical_path == 0 {
        return Err("dataflow-check: degenerate DAG report".into());
    }
    let spawned = pool.threads_spawned();
    if spawned != pool.size() as u64 {
        return Err(format!(
            "dataflow-check: pool spawned {spawned} thread(s) across {REPS} runs, \
             expected exactly {} (O(1) per pool, not O(ops))",
            pool.size()
        ));
    }
    println!(
        "dataflow-check: outputs bit-exact vs serial plan; {} thread(s) spawned across \
         {REPS} runs",
        spawned
    );
    Ok(())
}

/// `--shards` without `--shard-check`: shard-aware compile (each
/// region against its own target's pipeline, optionally tuned), one
/// sharded run over the topology's worker pool, then the per-shard
/// schedule and outputs.
fn run_sharded(
    program: &stripe::ir::Program,
    inputs: &std::collections::BTreeMap<String, Vec<f32>>,
    topo: &std::sync::Arc<stripe::hw::ShardTopology>,
    tune: bool,
) -> Result<(), String> {
    let sn = stripe::coordinator::compile_network_sharded(program, topo, false, tune)?;
    println!("{}", sn.summary());
    let t0 = std::time::Instant::now();
    let (out, report) = stripe::coordinator::run_sharded_network(
        &sn,
        inputs,
        &stripe::exec::ExecOptions::default(),
    )?;
    let dt = t0.elapsed();
    println!("{}", report.stats.summary_line());
    for (name, vals) in &out {
        let preview: Vec<String> = vals.iter().take(8).map(|v| format!("{v:.4}")).collect();
        println!("{name}[{}] = [{} ...]", vals.len(), preview.join(", "));
    }
    println!("executed in {dt:?}");
    Ok(())
}

/// `--shard-check`: compile the network across the shard topology with
/// per-region verification on, then require (a) bitwise identical
/// outputs vs the serial plan engine, (b) runtime inter-shard transfer
/// bytes exactly equal to the assignment's static prediction, (c) O(1)
/// pool thread spawns across repeat runs, and (d) a scrape whose
/// `stripe_shard_*` series reconcile. Exits nonzero on any failure —
/// `scripts/verify.sh` runs this as the `VERIFY_SHARD_SMOKE` gate.
fn shard_check(
    program: &stripe::ir::Program,
    inputs: &std::collections::BTreeMap<String, Vec<f32>>,
    topo: &std::sync::Arc<stripe::hw::ShardTopology>,
) -> Result<(), String> {
    const REPS: usize = 3;
    let sn = stripe::coordinator::compile_network_sharded(program, topo, true, false)?;
    println!("{}", sn.summary());
    let serial = stripe::exec::run_program_with(
        program,
        inputs,
        &stripe::exec::ExecOptions::default(),
    )
    .map_err(|e| e.to_string())?;
    let pool = stripe::exec::ComputePool::new(topo.total_units());
    let opts = stripe::exec::ExecOptions {
        compute: Some(pool.clone()),
        ..stripe::exec::ExecOptions::default()
    };
    let metrics = stripe::coordinator::Metrics::default();
    let mut last = None;
    for _ in 0..REPS {
        let r = stripe::coordinator::run_sharded_network(&sn, inputs, &opts)?;
        metrics.record_shard(&r.1.stats);
        last = Some(r);
    }
    let (out, report) = last.ok_or("shard-check needs at least one rep")?;
    if out != serial {
        return Err("shard-check: sharded and serial plan outputs disagree".into());
    }
    let stats = &report.stats;
    println!("shard-check: {}", stats.summary_line());
    if stats.transfer_bytes != stats.predicted_transfer_bytes {
        return Err(format!(
            "shard-check: runtime transfer {} B disagrees with the static prediction {} B",
            stats.transfer_bytes, stats.predicted_transfer_bytes
        ));
    }
    let spawned = pool.threads_spawned();
    if spawned != pool.size() as u64 {
        return Err(format!(
            "shard-check: pool spawned {spawned} thread(s) across {REPS} runs, \
             expected exactly {} (O(1) per pool, not O(ops))",
            pool.size()
        ));
    }
    let scrape = metrics.render_scrape();
    let line = stripe::coordinator::metrics::reconcile_scrape(&scrape)
        .map_err(|e| format!("shard-check: scrape does not reconcile: {e}"))?;
    println!("{line}");
    println!(
        "shard-check: outputs bit-exact vs serial plan across {} shard(s); transfer \
         {} B == predicted; {spawned} thread(s) spawned across {REPS} runs",
        topo.len(),
        stats.transfer_bytes
    );
    Ok(())
}

/// Autotune a network through the compile service, print the tuning
/// decision, and prove the tuned artifact is cached: repeat compiles
/// must add exactly N hits over whatever the first compile cost
/// (mirroring the single-flight contract — and with `--store-dir`, the
/// first compile may itself be a disk hit rather than a miss, which is
/// why the check measures the delta, not the absolute count). With
/// `--require-warm` the command additionally fails unless the compile
/// was served from the persistent store with zero tuning work — the
/// restart warm-start proof `scripts/verify.sh` uses as the
/// `VERIFY_STORE_SMOKE` gate (caching itself is `VERIFY_TUNE_SMOKE`).
fn cmd_tune(args: &Args) -> i32 {
    let run = || -> Result<(), String> {
        let p = load_net(args)?;
        let cfg = load_target(args)?;
        let store = open_store(args)?;
        let svc =
            CompileService::start_with_store(args.get_usize("workers", 2), 64, 0, store);
        let first = svc.compile_blocking_tuned(p.clone(), cfg.clone(), false)?;
        let tuning = first.tuning.as_ref().ok_or("tuned compile lost its report")?;
        print!("{}", tuning.summary());
        if let Some(st) = first.search_stats() {
            println!("{}", st.summary_line());
        }
        let hits_before = svc.metrics.total(Counter::Hits);
        const REPEATS: u64 = 2;
        for _ in 0..REPEATS {
            let again = svc.compile_blocking_tuned(p.clone(), cfg.clone(), false)?;
            if !std::sync::Arc::ptr_eq(&first, &again) {
                svc.shutdown();
                return Err("repeat tuned compile was not served from cache".into());
            }
        }
        let hit_delta = svc.metrics.total(Counter::Hits) - hits_before;
        let compiles = svc.metrics.total(Counter::CompilesOk);
        println!("metrics: {}", svc.metrics.snapshot());
        if let Some(s) = svc.store() {
            println!("{}", s.summary());
        }
        let store_hits = svc.store().map(|s| s.stats().hits).unwrap_or(0);
        svc.shutdown();
        if hit_delta != REPEATS {
            return Err(format!(
                "tuned config not cached: expected {REPEATS} hit(s) across the repeats, \
                 saw {hit_delta}"
            ));
        }
        println!("tuned config cached: {REPEATS} repeat(s) served from memory");
        if args.flag("require-warm") {
            if compiles != 0 || store_hits == 0 {
                return Err(format!(
                    "cold start: {compiles} compile(s) ran, {store_hits} store hit(s) \
                     (--require-warm expects 0 compiles and >= 1 store hit)"
                ));
            }
            println!("warm start: artifact served from the store, zero tuning candidates");
        }
        Ok(())
    };
    report(run())
}

fn cmd_validate(args: &Args) -> i32 {
    let run = || -> Result<(), String> {
        let file = args
            .positional()
            .get(1)
            .ok_or_else(|| "usage: stripe validate <file.stripe>".to_string())?;
        let src = std::fs::read_to_string(file).map_err(|e| format!("read {file}: {e}"))?;
        let p = stripe::ir::parser::parse_program(&src).map_err(|e| e.to_string())?;
        let findings = stripe::ir::validate::validate_program(&p);
        if findings.is_empty() {
            println!("{file}: OK ({} blocks)", p.block_count());
        }
        for f in &findings {
            println!("{f}");
        }
        if stripe::ir::validate::is_valid(&findings) {
            Ok(())
        } else {
            Err("validation failed".into())
        }
    };
    report(run())
}

fn cmd_fig1(args: &Args) -> i32 {
    let s = Scenario {
        kernels: args.get_u64("kernels", 12),
        architectures: args.get_u64("archs", 4),
        versions_per_arch: args.get_u64("versions", 3),
        shapes: args.get_u64("shapes", 20),
    };
    print!("{}", render_table(&s));
    0
}

/// Multi-tenant serving-tier demo: two tenants submit a burst with
/// repeats through the admission layer, then the scrape is printed
/// (`--metrics`) and reconciled — requests = hits + misses + rejects +
/// timeouts, globally and per tenant. Exits nonzero if the books don't
/// balance; `scripts/verify.sh` uses this as the `VERIFY_SERVE_SMOKE`
/// gate.
fn cmd_serve(args: &Args) -> i32 {
    let store = match open_store(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let config = ServeConfig {
        workers: args.get_usize("workers", 2),
        queue_depth: args.get_usize("queue-depth", 64),
        tenant_cap: args.get_usize("tenant-cap", 4),
        cache_bytes: args.get_u64("cache-bytes", 0),
        deadline: match args.get_u64("deadline-ms", 0) {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
        store,
    };
    println!(
        "serving tier: {} worker(s), queue depth {}, tenant cap {}, cache budget {}, deadline {:?}",
        config.workers,
        config.queue_depth,
        config.tenant_cap,
        if config.cache_bytes == 0 { "unlimited".to_string() } else { format!("{} B", config.cache_bytes) },
        config.deadline,
    );
    let server = Server::start(config);
    let opts = RequestOptions::default();
    // Two tenants, repeats included: alpha's repeat of fig4_conv and
    // beta's of conv_relu exercise the cache/single-flight path.
    let traffic: &[(&str, &str)] = &[
        ("alpha", "fig4_conv"),
        ("beta", "conv_relu"),
        ("alpha", "matmul"),
        ("alpha", "fig4_conv"),
        ("beta", "cnn"),
        ("beta", "conv_relu"),
        ("alpha", "mlp"),
    ];
    let mut rxs = Vec::new();
    for (tenant, net) in traffic {
        let p = match *net {
            "fig4_conv" => ops::fig4_conv_program(),
            "conv_relu" => ops::conv_relu_program(),
            "cnn" => ops::cnn_program(),
            "mlp" => ops::tiny_mlp_program(16, 32, 10),
            _ => ops::matmul_program(16, 16, 16),
        };
        match server.submit(*tenant, p, targets::cpu_cache(), &opts) {
            Ok(rx) => rxs.push((*tenant, *net, rx)),
            Err(e) => println!("  {tenant:<6} {net:<10} shed: {e}"),
        }
    }
    for (tenant, net, rx) in rxs {
        match rx.recv() {
            Ok(Ok(c)) => println!("  {tenant:<6} {net:<10} ok: {} passes", c.reports.len()),
            Ok(Err(e)) => println!("  {tenant:<6} {net:<10} failed: {e}"),
            Err(_) => println!("  {tenant:<6} {net:<10} dropped"),
        }
    }
    let stats = server.cache_stats();
    println!(
        "cache: {} artifact(s), {} B resident (budget {})",
        stats.entries,
        stats.bytes,
        if stats.budget == 0 { "unlimited".to_string() } else { format!("{} B", stats.budget) },
    );
    if let Some(s) = server.service().store() {
        println!("{}", s.summary());
    }
    println!("metrics: {}", server.metrics().snapshot());
    let scrape = server.render_scrape();
    if args.flag("metrics") {
        print!("{scrape}");
    }
    server.shutdown();
    match stripe::coordinator::metrics::reconcile_scrape(&scrape) {
        Ok(line) => {
            println!("{line}");
            0
        }
        Err(e) => {
            eprintln!("error: scrape does not reconcile: {e}");
            1
        }
    }
}

/// `stripe store stats|gc` — inspect or collect a persistent store
/// directory. `stats` rescans and fscks every resident entry, prints
/// the one-line summary, and exits nonzero if the books don't balance;
/// `gc` evicts oldest-modified-first down to `--store-budget` (0 =
/// report only).
fn cmd_store(args: &Args) -> i32 {
    let run = || -> Result<(), String> {
        let sub = args.positional().get(1).map(|s| s.as_str()).unwrap_or("stats");
        let dir = args
            .get("store-dir")
            .ok_or("usage: stripe store <stats|gc> --store-dir <dir> [--store-budget <bytes>]")?;
        let budget = args.get_u64("store-budget", 0);
        let store = ArtifactStore::open_with_budget(dir, budget)?;
        match sub {
            "stats" => {
                let (valid, problems) = store.fsck()?;
                println!("{}", store.summary());
                for p in &problems {
                    println!("  corrupt: {p}");
                }
                println!("fsck: {valid} valid entr(ies), {} corrupt", problems.len());
                if !store.stats().reconciles() {
                    return Err("store stats do not reconcile".into());
                }
                Ok(())
            }
            "gc" => {
                let r = store.gc(budget)?;
                println!(
                    "store gc: evicted {} entr(ies) / {} B; resident {} entr(ies) / {} B{}",
                    r.evicted,
                    r.evicted_bytes,
                    r.resident_entries,
                    r.resident_bytes,
                    if budget == 0 { " (report only: --store-budget 0)" } else { "" },
                );
                Ok(())
            }
            other => Err(format!("unknown store subcommand {other:?} (stats|gc)")),
        }
    };
    report(run())
}

fn report(r: Result<(), String>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// Figure regeneration (computation shared with benches via the
/// library; printing lives here).
mod figs {
    use std::collections::BTreeMap;
    use stripe::cost::cacheline::{tiling_cost, CostParams};
    use stripe::frontend::ops;
    use stripe::ir::builder::fig5_conv_block;
    use stripe::ir::printer::block_to_string;
    use stripe::passes::tile::{apply_tiling, TileOptions};

    pub fn fig2() -> i32 {
        println!("Figure 2 — two tilings of a 12x6 tensor by nested polyhedral blocks\n");
        let p = ops::fig2_copy_program();
        let stripe::ir::Statement::Block(b) = &p.main.stmts[0] else { unreachable!() };
        let tiles: BTreeMap<String, u64> =
            [("e0".to_string(), 3u64), ("e1".to_string(), 2)].into();
        let tiled = apply_tiling(b, &tiles, &TileOptions::default());
        println!("-- tiling A: inner block steps one unit; outer steps 3x2 tiles");
        print_tile_map(12, 6, |x, y| (x / 3) * 3 + (y / 2));
        println!("-- tiling B: outer steps a unit; inner strides 4x3 (interleaved)");
        print_tile_map(12, 6, |x, y| (x % 3) * 3 + (y % 2));
        println!(
            "tiled IR depth: {} (see `stripe fig5` for the printed nest)",
            tiled.depth()
        );
        println!("Both decompositions validate as hierarchically parallelizable (Def. 2);");
        println!("see benches/fig2_tilings.rs for the overlap proofs.");
        0
    }

    fn print_tile_map(h: u64, w: u64, tile_of: impl Fn(u64, u64) -> u64) {
        for x in 0..h {
            let row: Vec<String> = (0..w).map(|y| format!("{:>2}", tile_of(x, y))).collect();
            println!("  {}", row.join(" "));
        }
        println!();
    }

    pub fn fig3() -> i32 {
        println!("Figure 3 — memory regions per nesting depth (dc_accel target)\n");
        let p = ops::fig4_conv_program();
        let cfg = stripe::hw::targets::dc_accel();
        let c = stripe::coordinator::compile_network(&p, &cfg, false).expect("compile");
        let mut depth_regions: Vec<(usize, String, u64)> = Vec::new();
        for op in c.program.ops() {
            collect_regions(op, 1, &mut depth_regions);
        }
        println!("{:<6} {:<28} {:>16}", "depth", "block", "view elems/iter");
        for (d, name, elems) in depth_regions {
            println!("{d:<6} {name:<28} {elems:>16}");
        }
        println!("\nDepth 1 ≈ whole-tensor DMA; deeper levels shrink toward the");
        println!("per-PE stencil registers — the Fig. 3 columns.");
        0
    }

    fn collect_regions(b: &stripe::ir::Block, depth: usize, out: &mut Vec<(usize, String, u64)>) {
        let elems: u64 = b.refs.iter().map(|r| r.ttype.elems()).sum();
        out.push((depth, b.name.clone(), elems));
        for c in b.child_blocks() {
            collect_regions(c, depth + 1, out);
        }
    }

    pub fn fig4() -> i32 {
        println!("Figure 4 — tiling costs for the 3x3 conv (line=8 elems, cap=512 elems)\n");
        let b = fig5_conv_block();
        let params = CostParams::default();
        println!(
            "{:<10} {:>10} {:>12} {:>12} {:>10} {:>12}  {}",
            "tile", "tiles", "lines/tile", "total lines", "MACs", "lines/MAC", "feasible"
        );
        for (tx, ty) in [(1u64, 8u64), (3, 4), (6, 16), (12, 2)] {
            let tile: BTreeMap<String, u64> =
                [("x".to_string(), tx), ("y".to_string(), ty)].into();
            let c = tiling_cost(&b, &tile, &params);
            let per_tile: u64 = c.lines_per_tile.iter().map(|(_, l)| l).sum();
            println!(
                "{:<10} {:>10} {:>12} {:>12} {:>10} {:>12.6}  {} (mem {} elems)",
                format!("{tx}x{ty}"),
                c.tiles,
                per_tile,
                c.total_lines,
                c.macs,
                c.cost(),
                if c.feasible { "yes" } else { "NO" },
                c.tile_mem_elems,
            );
        }
        let (best, stats) = stripe::cost::search::best_tiling(
            &b,
            &["x".to_string(), "y".to_string()],
            &params,
            stripe::cost::search::SearchSpace::Exhaustive,
            &BTreeMap::new(),
            100_000,
        );
        let best = best.expect("feasible tiling");
        println!(
            "\nexhaustive search ({} tilings): best {:?} at {:.6} lines/MAC",
            stats.evaluated,
            best.tile,
            best.cost()
        );
        0
    }

    pub fn fig5() -> i32 {
        println!("Figure 5 — Stripe code before and after the tiling pass\n");
        let b = fig5_conv_block();
        println!("(a) before tiling:\n");
        println!("{}", block_to_string(&b));
        let tile: BTreeMap<String, u64> = [("x".to_string(), 3), ("y".to_string(), 4)].into();
        let tiled = apply_tiling(&b, &tile, &TileOptions::default());
        println!("(b) after tiling (3x4):\n");
        println!("{}", block_to_string(&tiled));
        0
    }
}
